"""AdamW with decoupled weight decay + cosine LR schedule (self-contained —
no optax in this environment).  Optimizer state shardings mirror params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt", "apply_updates", "lr_at"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def lr_at(step, cfg: AdamWConfig):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step
    lr = lr_at(step, cfg)
    b1c = 1 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    b2c = 1 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(mu=new_m, nu=new_v, step=step + 1), metrics
