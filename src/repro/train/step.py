"""Train / prefill / decode step builders — the functions the dry-run lowers.

``make_train_step``: CE loss (pad-masked, MoE-aux added), grads, AdamW.
``make_prefill_step``: forward only, returns logits (inference prefill).
``make_serve_step``: one-token decode against a KV cache.
Gradient compression (int8 error-feedback, cross-pod) is applied when
``compress_grads`` — see repro.distributed.compression.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import build
from .optim import AdamWConfig, OptState, apply_updates

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step", "loss_fn"]

_AUX_WEIGHT = 0.01


def loss_fn(model, params, batch: Dict[str, Any], cfg: ModelConfig,
            unroll: bool = False):
    labels = batch["labels"]
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    logits, aux = model.apply(params, **inputs, remat=True, unroll=unroll)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < cfg.vocab_size)
    nll = jnp.where(mask, nll, 0.0)
    ce = nll.sum() / jnp.maximum(1, mask.sum())
    return ce + _AUX_WEIGHT * aux, ce


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    compress_grads: bool = False, unroll: bool = False):
    model = build(cfg)
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state: OptState, batch):
        (loss, ce), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, cfg, unroll=unroll),
            has_aux=True)(params)
        if compress_grads:
            from ..distributed.compression import compress_tree_int8

            grads = compress_tree_int8(grads)
        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "ce": ce, **metrics}
        return params, opt_state, metrics

    return model, train_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False):
    """Build ``(model, prefill_step)``: a full forward pass over a prompt
    batch that returns only the last position's logits — the serving
    prefill phase that seeds the KV cache for ``make_serve_step``."""
    model = build(cfg)

    def prefill_step(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _ = model.apply(params, **inputs, remat=False, unroll=unroll)
        # return only the last position's logits (what serving needs)
        return logits[:, -1, :]

    return model, prefill_step


def make_serve_step(cfg: ModelConfig, unroll: bool = False):
    """Build ``(model, serve_step)``: one greedy decode step — append the
    incoming token to the KV cache, return ``(next_token, cache)``."""
    model = build(cfg)

    def serve_step(params, cache, inputs):
        logits, cache = model.decode_step(params, cache, **inputs,
                                          unroll=unroll)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_token, cache

    return model, serve_step
