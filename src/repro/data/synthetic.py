"""Synthetic datasets with controlled statistics (DESIGN.md §9).

The original SIFT1M/Deep1M/FB-ssnpp are not downloadable offline; id
compression rates depend only on (N, K, cluster-size distribution), which a
GMM with matched imbalance reproduces; PQ-code compressibility (Fig 3)
depends on within-cluster vector concentration, which ``concentration``
controls.  Three presets mirror the paper's datasets:

  * ``sift-like``  — 128-d, blockwise structure (4x4x8 gradient histograms
                     approximated by non-isotropic block covariances),
                     strong cluster concentration (codes compressible);
  * ``deep-like``  — 96-d isotropic GMM, milder concentration;
  * ``ssnpp-like`` — 256-d, heavy-tailed cluster sizes, near-uniform codes
                     (the "hard to exploit" regime the paper reports).
"""

from __future__ import annotations

import numpy as np

__all__ = ["make_dataset", "make_tokens", "PRESETS"]

PRESETS = {
    "sift-like": dict(d=128, n_modes=2048, concentration=0.25, block=8, heavy=False),
    "deep-like": dict(d=96, n_modes=2048, concentration=0.45, block=0, heavy=False),
    "ssnpp-like": dict(d=256, n_modes=2048, concentration=0.9, block=0, heavy=True),
}


def make_dataset(preset: str, n: int, n_queries: int = 1000, seed: int = 0):
    """Returns (base (n,d) f32, queries (nq,d) f32)."""
    p = PRESETS[preset]
    rng = np.random.default_rng(seed)
    d, modes = p["d"], p["n_modes"]
    centers = rng.standard_normal((modes, d)).astype(np.float32)
    if p["heavy"]:
        w = rng.pareto(1.2, size=modes) + 0.05
    else:
        w = rng.gamma(4.0, 1.0, size=modes) + 0.05
    w = w / w.sum()

    def sample(count):
        which = rng.choice(modes, size=count, p=w)
        pts = centers[which]
        noise = rng.standard_normal((count, d)).astype(np.float32)
        if p["block"]:
            # blockwise scaling: later dims within a block get less energy
            scale = np.tile(
                np.linspace(1.0, 0.35, p["block"]), d // p["block"]
            ).astype(np.float32)
            noise *= scale[None]
        return pts + p["concentration"] * noise

    return sample(n), sample(n_queries)


def make_tokens(n_tokens: int, vocab: int, seed: int = 0, zipf_a: float = 1.2):
    """Zipfian token stream for LM training examples."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(zipf_a, size=n_tokens)
    return np.minimum(ranks - 1, vocab - 1).astype(np.int32)
