"""Deterministic, resumable, sharded token pipeline.

Synthetic Zipf token streams (offline container) with the properties a real
pipeline needs at scale: (a) the batch for step t is a pure function of
(seed, step) — restart-safe without data loss or duplication; (b) each data
shard draws a disjoint slice (host-sharded loading); (c) state is one
integer, carried in the checkpoint manifest.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0
    zipf_a: float = 1.3
    n_shards: int = 1
    shard: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        b = self.batch // self.n_shards
        ranks = rng.zipf(self.zipf_a, size=(b, self.seq_len + 1))
        toks = np.minimum(ranks - 1, self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict) -> "TokenPipeline":
        self.seed = int(state["seed"])
        self.step = int(state["step"])
        return self
