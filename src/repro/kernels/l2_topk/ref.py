"""Pure-jnp oracle for the L2 top-1 kernel."""

import jax.numpy as jnp


def l2_top1_ref(queries, centroids):
    d = (
        jnp.sum(queries.astype(jnp.float32) ** 2, 1, keepdims=True)
        - 2.0 * queries.astype(jnp.float32) @ centroids.astype(jnp.float32).T
        + jnp.sum(centroids.astype(jnp.float32) ** 2, 1)[None]
    )
    return jnp.argmin(d, 1).astype(jnp.int32), jnp.min(d, 1)
