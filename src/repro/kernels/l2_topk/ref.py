"""Pure-jnp oracles for the L2 kernels."""

import jax.numpy as jnp


def _l2_matrix(queries, cands):
    return (
        jnp.sum(queries.astype(jnp.float32) ** 2, 1, keepdims=True)
        - 2.0 * queries.astype(jnp.float32) @ cands.astype(jnp.float32).T
        + jnp.sum(cands.astype(jnp.float32) ** 2, 1)[None]
    )


def l2_top1_ref(queries, centroids):
    """Oracle for :func:`l2_top1` — one dense distance matrix + argmin."""
    d = _l2_matrix(queries, centroids)
    return jnp.argmin(d, 1).astype(jnp.int32), jnp.min(d, 1)


def l2_dist_ref(queries, cands):
    """queries (NQ, d), cands (N, d) -> (NQ, N) f32 distance matrix."""
    return _l2_matrix(queries, cands)
