"""Pallas TPU kernel: tiled L2 distance + fused arg-top1.

The IVF coarse-probe and k-means assignment hot loop: for a tile of
queries, compute squared L2 distances to all K centroids with one MXU
matmul (||q||^2 - 2 q.c + ||c||^2) and reduce to (argmin, min) without
writing the (BLOCK_Q, K) distance tile to HBM.

Grid: (ceil(NQ / BLOCK_Q),).  Centroids (and their norms) are VMEM-resident
across grid steps (constant index_map): K*d*4 bytes — e.g. 2048 x 128 f32
= 1 MB.  MXU dims: BLOCK_Q x d x K, all multiples of 128 by construction
(ops.py pads d and K).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["l2_top1_pallas", "l2_dist_pallas", "BLOCK_Q", "BLOCK_N"]

BLOCK_Q = 256
BLOCK_N = 512


def _l2_kernel(q_ref, c_ref, cn_ref, idx_ref, val_ref):
    q = q_ref[...]                       # (BLOCK_Q, d)
    c = c_ref[...]                       # (K, d)
    cn = cn_ref[...]                     # (K,)
    dots = jnp.dot(q, c.T, preferred_element_type=jnp.float32)
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    dist = qn - 2.0 * dots + cn[None, :]
    idx_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.int32)
    val_ref[...] = jnp.min(dist, axis=1)


def l2_top1_pallas(queries: jnp.ndarray, centroids: jnp.ndarray,
                   block_q: int = BLOCK_Q, interpret: bool = True):
    """queries (NQ, d), centroids (K, d) -> (argmin (NQ,) i32, min (NQ,) f32)."""
    nq, d = queries.shape
    k = centroids.shape[0]
    assert nq % block_q == 0
    cn = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)
    grid = (nq // block_q,)
    return pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((nq,), jnp.float32),
        ],
        interpret=interpret,
    )(queries, centroids, cn)


def _l2_dist_kernel(q_ref, c_ref, cn_ref, out_ref):
    q = q_ref[...]                       # (block_q, d)
    c = c_ref[...]                       # (block_n, d)
    cn = cn_ref[...]                     # (block_n,)
    dots = jnp.dot(q, c.T, preferred_element_type=jnp.float32)
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    out_ref[...] = qn - 2.0 * dots + cn[None, :]


def l2_dist_pallas(queries: jnp.ndarray, cands: jnp.ndarray,
                   block_q: int = BLOCK_Q, block_n: int = BLOCK_N,
                   interpret: bool = True):
    """queries (NQ, d), cands (N, d) -> full (NQ, N) squared-L2 matrix.

    The batched-IVF scan shape: one query tile against candidate tiles
    gathered from the deduplicated probed clusters.  Unlike
    :func:`l2_top1_pallas` the distance tile IS the output (the scan layer
    does its own per-query masked top-k over a padded candidate block), so
    the grid is 2-D and each step emits a (block_q, block_n) tile.
    """
    nq, d = queries.shape
    n = cands.shape[0]
    assert nq % block_q == 0 and n % block_n == 0
    cn = jnp.sum(cands.astype(jnp.float32) ** 2, axis=1)
    grid = (nq // block_q, n // block_n)
    return pl.pallas_call(
        _l2_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, n), jnp.float32),
        interpret=interpret,
    )(queries, cands, cn)
