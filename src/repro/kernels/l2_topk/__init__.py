from .ops import l2_dist, l2_top1
from .ref import l2_dist_ref, l2_top1_ref

__all__ = ["l2_top1", "l2_top1_ref", "l2_dist", "l2_dist_ref"]
