"""Jitted wrappers: pad queries/candidates to block sizes, d/K to MXU sizes."""

import functools

import jax
import jax.numpy as jnp

from .kernel import BLOCK_N, BLOCK_Q, l2_dist_pallas, l2_top1_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "block_q"))
def l2_top1(queries, centroids, block_q: int = BLOCK_Q, interpret: bool = True):
    """Nearest centroid per query: ``(argmin (nq,) i32, min_d (nq,) f32)``
    over squared L2, padded to kernel block shapes."""
    nq, d = queries.shape
    k = centroids.shape[0]
    if nq == 0 or k == 0:
        return (jnp.zeros((nq,), jnp.int32),
                jnp.full((nq,), jnp.inf, jnp.float32))
    pad_q = (-nq) % block_q
    pad_d = (-d) % 128
    pad_k = (-k) % 128
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, pad_d)))
    # padded centroids must not win the argmin: push them to +inf distance
    cp = jnp.pad(centroids.astype(jnp.float32), ((0, pad_k), (0, pad_d)))
    if pad_k:
        cp = cp.at[k:, 0].set(3e18)
    idx, val = l2_top1_pallas(qp, cp, block_q=block_q, interpret=interpret)
    return idx[:nq], val[:nq]


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_q", "block_n"))
def l2_dist(queries, cands, block_q: int = BLOCK_Q, block_n: int = BLOCK_N,
            interpret: bool = True):
    """queries (NQ, d), cands (N, d) -> (NQ, N) f32 squared L2 distances.

    Zero-pads d (distance-preserving) and both row counts to block
    multiples; padded rows/columns are sliced off, so callers never see
    them.  NQ = 0 or N = 0 short-circuits to an empty result (Pallas grids
    must be non-empty).
    """
    nq, d = queries.shape
    n = cands.shape[0]
    if nq == 0 or n == 0:
        return jnp.zeros((nq, n), jnp.float32)
    pad_q = (-nq) % block_q
    pad_n = (-n) % block_n
    pad_d = (-d) % 128
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, pad_d)))
    cp = jnp.pad(cands.astype(jnp.float32), ((0, pad_n), (0, pad_d)))
    out = l2_dist_pallas(qp, cp, block_q=block_q, block_n=block_n,
                         interpret=interpret)
    return out[:nq, :n]
