"""Jitted wrapper: pads queries to BLOCK_Q and d/K to MXU-friendly sizes."""

import functools

import jax
import jax.numpy as jnp

from .kernel import BLOCK_Q, l2_top1_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "block_q"))
def l2_top1(queries, centroids, block_q: int = BLOCK_Q, interpret: bool = True):
    nq, d = queries.shape
    k = centroids.shape[0]
    pad_q = (-nq) % block_q
    pad_d = (-d) % 128
    pad_k = (-k) % 128
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, pad_d)))
    # padded centroids must not win the argmin: push them to +inf distance
    cp = jnp.pad(centroids.astype(jnp.float32), ((0, pad_k), (0, pad_d)))
    if pad_k:
        cp = cp.at[k:, 0].set(3e18)
    idx, val = l2_top1_pallas(qp, cp, block_q=block_q, interpret=interpret)
    return idx[:nq], val[:nq]
