"""Pure-jnp oracle for the PQ ADC kernel."""

import jax.numpy as jnp


def pq_adc_ref(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """codes (N, m) int, lut (m, ksub) f32 -> (N,) f32 distances."""
    m = codes.shape[1]
    cols = [lut[j][codes[:, j]] for j in range(m)]
    return jnp.stack(cols, axis=0).sum(axis=0).astype(jnp.float32)
