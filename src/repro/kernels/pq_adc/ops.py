"""Jitted public wrapper for the PQ ADC kernel (pads N to the block size)."""

import functools

import jax
import jax.numpy as jnp

from .kernel import BLOCK_N, pq_adc_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def pq_adc(codes, lut, block_n: int = BLOCK_N, interpret: bool = True):
    """codes (N, m) any int dtype, lut (m, ksub) f32 -> (N,) f32.

    N = 0 short-circuits (Pallas grids must be non-empty).
    """
    n = codes.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.float32)
    pad = (-n) % block_n
    codes = jnp.pad(codes.astype(jnp.int32), ((0, pad), (0, 0)))
    out = pq_adc_pallas(codes, lut.astype(jnp.float32),
                        block_n=block_n, interpret=interpret)
    return out[:n]
