"""Pallas TPU kernel: fused PQ asymmetric-distance scan.

Faiss scans inverted lists scalar-wise (one table lookup per subquantizer
per code).  The TPU adaptation keeps the (m, 256) LUT resident in VMEM and
turns the per-subquantizer gather into a one-hot contraction that the MXU
executes at peak — the standard lookup->matmul rewrite for systolic
hardware.  Codes stream HBM->VMEM in (BLOCK_N, m) tiles; each tile emits
BLOCK_N distances, so distances never round-trip through HBM.

Grid: (ceil(N / BLOCK_N),); LUT is broadcast to every grid step via a
constant index_map.  VMEM per step: BLOCK_N*m (codes, int32) +
m*256*4 (LUT) + BLOCK_N*4 (out) ~= 0.6 MB at BLOCK_N=1024, m=16.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pq_adc_pallas", "BLOCK_N"]

BLOCK_N = 1024


def _adc_kernel(codes_ref, lut_ref, out_ref, *, ksub: int):
    codes = codes_ref[...]            # (BLOCK_N, m) int32
    lut = lut_ref[...]                # (m, ksub) f32
    onehot = jax.nn.one_hot(codes, ksub, dtype=lut.dtype)  # (BLOCK_N, m, ksub)
    out_ref[...] = jnp.einsum(
        "nmk,mk->n", onehot, lut, preferred_element_type=jnp.float32
    )


def pq_adc_pallas(codes: jnp.ndarray, lut: jnp.ndarray,
                  block_n: int = BLOCK_N, interpret: bool = True) -> jnp.ndarray:
    """codes (N, m) int32, lut (m, ksub) f32 -> (N,) f32 distances.

    N must be a multiple of block_n (ops.py pads).
    """
    n, m = codes.shape
    ksub = lut.shape[1]
    assert n % block_n == 0
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_kernel, ksub=ksub),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m, ksub), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(codes, lut)
