"""Pure-jnp oracle for batched bitvector rank."""

import jax.numpy as jnp


def wt_rank_ref(bits: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """bits (N,) 0/1; queries (Q,) positions -> #ones in [0, q)."""
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32),
                           jnp.cumsum(bits.astype(jnp.int32))])
    return cum[queries]
