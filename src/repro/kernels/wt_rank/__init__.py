from .ops import pack_bits_u32, wt_rank
from .ref import wt_rank_ref

__all__ = ["wt_rank", "wt_rank_ref", "pack_bits_u32"]
