"""Pallas TPU kernel: batched rank1 queries on a bit-packed vector.

Wavelet-tree select/rank is the paper's full-random-access path (§4.1);
rank over a packed bitvector = superblock prefix + in-range word popcounts.
TPU adaptation: SWAR popcount on uint32 words (the VPU has no popcount
instruction; the standard 4-op bit-slide is used), queries processed as a
(BLOCK_Q,) vector, the <=16 words between superblock boundary and the query
position handled by an unrolled masked loop of vector gathers.

Inputs: words (W,) u32 (packed bits), super (S,) i32 (cumulative ones at
every 16-word boundary), queries (Q,) i32 (bit positions).  Output: (Q,) i32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["wt_rank_pallas", "BLOCK_Q", "WORDS_PER_SUPER"]

BLOCK_Q = 256
WORDS_PER_SUPER = 16


def _popcount32(v: jnp.ndarray) -> jnp.ndarray:
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> jnp.uint32(24)


def _rank_kernel(words_ref, super_ref, q_ref, out_ref):
    q = q_ref[...].astype(jnp.int32)                 # (BLOCK_Q,) bit positions
    word_idx = q >> 5
    bit_idx = (q & 31).astype(jnp.uint32)
    sup_idx = word_idx // WORDS_PER_SUPER
    base_word = sup_idx * WORDS_PER_SUPER
    acc = jnp.take(super_ref[...], sup_idx).astype(jnp.uint32)
    words = words_ref[...]
    for j in range(WORDS_PER_SUPER):                 # unrolled masked scan
        w = jnp.take(words, base_word + j)
        full = (base_word + j) < word_idx
        partial = (base_word + j) == word_idx
        pmask = (jnp.uint32(1) << bit_idx) - jnp.uint32(1)
        cnt_full = _popcount32(w)
        cnt_part = _popcount32(w & pmask)
        acc = acc + jnp.where(full, cnt_full, 0) + jnp.where(partial, cnt_part, 0)
    out_ref[...] = acc.astype(jnp.int32)


def wt_rank_pallas(words, super_cum, queries, block_q: int = BLOCK_Q,
                   interpret: bool = True):
    nq = queries.shape[0]
    assert nq % block_q == 0
    W = words.shape[0]
    S = super_cum.shape[0]
    return pl.pallas_call(
        _rank_kernel,
        grid=(nq // block_q,),
        in_specs=[
            pl.BlockSpec((W,), lambda i: (0,)),
            pl.BlockSpec((S,), lambda i: (0,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(words, super_cum, queries)
