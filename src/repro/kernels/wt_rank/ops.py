"""Jitted wrapper: packs bits, builds superblock cums, pads queries."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import BLOCK_Q, WORDS_PER_SUPER, wt_rank_pallas


def pack_bits_u32(bits: np.ndarray):
    """bits (N,) 0/1 -> (words u32 (W,), super_cum i32 (S,)) little-endian."""
    n = len(bits)
    W = -(-n // 32)
    pad = np.zeros(W * 32, np.uint8)
    pad[:n] = bits
    words = pad.reshape(W, 32).astype(np.uint32)
    words = (words << np.arange(32, dtype=np.uint32)).sum(axis=1, dtype=np.uint32)
    # pad words to a superblock multiple (+1 slack superblock for gathers)
    Wp = (-(-W // WORDS_PER_SUPER) + 1) * WORDS_PER_SUPER
    words = np.concatenate([words, np.zeros(Wp - W, np.uint32)])
    counts = np.bitwise_count(words).astype(np.int64)
    cum = np.concatenate([[0], np.cumsum(counts)])
    super_cum = cum[::WORDS_PER_SUPER][: Wp // WORDS_PER_SUPER + 1].astype(np.int32)
    return words, super_cum


@functools.partial(jax.jit, static_argnames=("interpret",))
def wt_rank(words, super_cum, queries, interpret: bool = True):
    """``rank1(i)`` for each query position over the packed bitvector:
    superblock cumulative popcounts + an in-block popcount on device."""
    nq = queries.shape[0]
    pad = (-nq) % BLOCK_Q
    q = jnp.pad(queries.astype(jnp.int32), (0, pad))
    out = wt_rank_pallas(words, super_cum, q, interpret=interpret)
    return out[:nq]
