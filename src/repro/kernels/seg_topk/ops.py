"""Jitted wrappers for the segmented top-k select (Pallas + XLA fallback).

Both engines implement one contract::

    seg_topk(dists (NQ, N), lens (NQ,), k) -> (vals (NQ, k) f32 ascending,
                                               idx  (NQ, k) i32)

Row ``i``'s columns at or past ``lens[i]`` count as ``+inf``; selection
order is the lexicographic ``(value asc, column asc)`` minimum, so the
two engines are **bit-identical** for every input — including rows whose
genuine distances are ``+inf`` and rows shorter than ``k`` (slots past
the ``lens[i]`` real candidates come back as ``val=+inf`` pointing at the
lowest masked/padding columns).  Callers that must distinguish a real
``+inf`` hit from padding filter by ``idx < lens[i]`` — that is exactly
what the scan layer's device-select path does.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import SEG_BLOCK_Q, seg_topk_pallas

__all__ = ["seg_topk", "seg_topk_xla"]


@functools.partial(jax.jit, static_argnames=("k", "block_q", "interpret"))
def seg_topk(dists, lens, k: int, block_q: int = SEG_BLOCK_Q,
             interpret: bool = True):
    """Pallas engine: pad rows/columns to kernel shape, select on device."""
    nq, n = dists.shape
    if nq == 0 or k == 0:
        return (jnp.full((nq, k), jnp.inf, jnp.float32),
                jnp.zeros((nq, k), jnp.int32))
    lens = jnp.minimum(lens.astype(jnp.int32), n)
    n_eff = max(n, k)
    pad_q = (-nq) % block_q
    pad_n = (-n_eff) % 128 + (n_eff - n)
    dp = jnp.pad(dists.astype(jnp.float32), ((0, pad_q), (0, pad_n)))
    lp = jnp.pad(lens, (0, pad_q))          # padding rows: lens 0, all +inf
    vals, idx = seg_topk_pallas(dp, lp, k, block_q=block_q,
                                interpret=interpret)
    return vals[:nq], idx[:nq]


@functools.partial(jax.jit, static_argnames=("k",))
def seg_topk_xla(dists, lens, k: int):
    """XLA engine: ``lax.top_k`` of the negated masked row.

    ``lax.top_k`` breaks value ties (including at ``-inf``) toward the
    lower index, which is the kernel's ``(value, column)`` order exactly.
    """
    nq, n = dists.shape
    if nq == 0 or k == 0:
        return (jnp.full((nq, k), jnp.inf, jnp.float32),
                jnp.zeros((nq, k), jnp.int32))
    lens = jnp.minimum(lens.astype(jnp.int32), n)
    cols = jnp.arange(n, dtype=jnp.int32)[None, :]
    masked = jnp.where(cols < lens[:, None], dists.astype(jnp.float32),
                       jnp.inf)
    if n < k:                                # widen with masked columns
        masked = jnp.pad(masked, ((0, 0), (0, k - n)),
                         constant_values=jnp.inf)
    neg, idx = jax.lax.top_k(-masked, k)
    return -neg, idx.astype(jnp.int32)
