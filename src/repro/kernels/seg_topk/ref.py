"""Pure-jnp oracle for the segmented top-k select.

One stable argsort over the masked row — JAX sorts are always stable, so
ties (including ties at ``+inf``) keep ascending-column order, the same
``(value asc, column asc)`` contract the Pallas kernel and the
``lax.top_k`` fallback implement.  O(N log N) per row; test use only.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["seg_topk_ref"]


def seg_topk_ref(dists: jnp.ndarray, lens: jnp.ndarray, k: int):
    """dists (NQ, N), lens (NQ,) -> (vals (NQ, k) f32, idx (NQ, k) i32)."""
    nq, n = dists.shape
    cols = jnp.arange(n, dtype=jnp.int32)[None, :]
    masked = jnp.where(cols < lens[:, None], dists.astype(jnp.float32),
                       jnp.inf)
    if n < k:                                # widen with masked columns
        masked = jnp.pad(masked, ((0, 0), (0, k - n)),
                         constant_values=jnp.inf)
    order = jnp.argsort(masked, axis=1)[:, :k].astype(jnp.int32)
    vals = jnp.take_along_axis(masked, order, axis=1)
    return vals, order
