"""Segmented top-k select — the device half of the scan engines' top-k.

``seg_topk`` (Pallas) and ``seg_topk_xla`` (``lax.top_k`` fallback)
reduce padded per-query candidate rows to their ``k`` smallest
``(value, column)`` pairs on device, bit-identically to each other; see
``ops.py`` for the full contract and ``repro.ann.scan`` for the consumer.
"""

from .kernel import SEG_BLOCK_Q, seg_topk_pallas
from .ops import seg_topk, seg_topk_xla
from .ref import seg_topk_ref

__all__ = ["seg_topk", "seg_topk_xla", "seg_topk_ref", "seg_topk_pallas",
           "SEG_BLOCK_Q"]
