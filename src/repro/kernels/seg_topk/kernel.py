"""Pallas TPU kernel: segmented top-k select over padded distance rows.

The device half of the scan engines' "never ship the ``(qb, C_pad)``
block to the host" contract: each query row holds ``lens[i]`` valid
candidate distances followed by padding, and the kernel reduces the row
to its ``k`` smallest entries *on device*, so only ``(nq, k)`` values and
columns cross to the host.

Selection order is the lexicographic ``(value asc, column asc)`` minimum
— the same order ``jax.lax.top_k`` of the negated row produces (ties,
including ties at ``+inf``, go to the lower column) — so the Pallas
kernel and the XLA fallback in ``ops.py`` are bit-identical, which is
what lets the scan layer swap engines without perturbing results.

Grid: (ceil(NQ / block_q),).  Each step holds one full ``(block_q, N)``
row tile in VMEM and runs ``k`` masked argmin iterations
(``jax.lax.fori_loop``): per iteration one row minimum, one lowest-
column-attaining-it reduction (this also breaks ``+inf`` ties the way
``top_k`` does — value masking alone cannot exclude already-taken
``+inf`` entries), then the chosen column is marked taken.  ``k`` is a
compile-time constant; callers bucket it to bound retraces.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["seg_topk_pallas", "SEG_BLOCK_Q"]

# a full row tile lives in VMEM: block_q * N * ~13 bytes (f32 + bool +
# int32 iota + scratch).  8 rows keep N up to ~100k inside a TPU core's
# VMEM; the scan layer's candidate rows are far narrower.
SEG_BLOCK_Q = 8


def _seg_topk_kernel(d_ref, len_ref, vals_ref, idx_ref, *, k: int):
    d = d_ref[...].astype(jnp.float32)          # (bq, n)
    ln = len_ref[...]                           # (bq,)
    bq, n = d.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, n), 1)
    d = jnp.where(cols < ln[:, None], d, jnp.inf)
    tcol = jax.lax.broadcasted_iota(jnp.int32, (bq, k), 1)

    def body(t, carry):
        taken, vals, idxs = carry
        avail = ~taken
        v = jnp.where(avail, d, jnp.inf)
        m = jnp.min(v, axis=1)                  # row minimum over untaken
        # lowest untaken column attaining it: breaks value ties by column
        # AND excludes taken +inf entries (their value alone could not)
        at = avail & (v == m[:, None])
        j = jnp.min(jnp.where(at, cols, n), axis=1).astype(jnp.int32)
        j = jnp.minimum(j, n - 1)               # k > n guard (ops pads n >= k)
        taken = taken | (cols == j[:, None])
        vals = jnp.where(tcol == t, m[:, None], vals)
        idxs = jnp.where(tcol == t, j[:, None], idxs)
        return taken, vals, idxs

    init = (jnp.zeros((bq, n), jnp.bool_),
            jnp.full((bq, k), jnp.inf, jnp.float32),
            jnp.zeros((bq, k), jnp.int32))
    _, vals, idxs = jax.lax.fori_loop(0, k, body, init)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def seg_topk_pallas(dists: jnp.ndarray, lens: jnp.ndarray, k: int,
                    block_q: int = SEG_BLOCK_Q, interpret: bool = True):
    """dists (NQ, N) f32, lens (NQ,) i32 -> (vals (NQ, k) f32, idx (NQ, k) i32).

    ``NQ`` must be a ``block_q`` multiple and ``N >= k`` (``ops.py`` pads
    both).  Row ``i``'s columns at or past ``lens[i]`` count as ``+inf``.
    """
    nq, n = dists.shape
    assert nq % block_q == 0 and n >= k
    grid = (nq // block_q,)
    return pl.pallas_call(
        functools.partial(_seg_topk_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, n), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        interpret=interpret,
    )(dists, lens)
