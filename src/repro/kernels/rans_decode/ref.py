"""Pure-jnp oracle for the interleaved rANS decoder (same math, lax.scan)."""

import jax
import jax.numpy as jnp


def rans_decode_ref(heads, words, sym_t, freq_t, start_t, rows: int, r: int):
    """Oracle for :func:`rans_decode` — the same per-stream state update
    as the kernel, expressed as one ``lax.scan`` over symbols."""
    mask = jnp.uint32((1 << r) - 1)
    low = jnp.uint32(1 << 16)

    def step(carry, _):
        heads, ptr = carry
        cf = heads & mask
        sym = sym_t[cf.astype(jnp.int32)]
        f = freq_t[cf.astype(jnp.int32)].astype(jnp.uint32)
        c = start_t[cf.astype(jnp.int32)].astype(jnp.uint32)
        heads = f * (heads >> jnp.uint32(r)) + cf - c
        need = heads < low
        k = jnp.cumsum(need.astype(jnp.int32)) - need.astype(jnp.int32)
        w = words[ptr + k].astype(jnp.uint32)
        heads = jnp.where(need, (heads << jnp.uint32(16)) | w, heads)
        ptr = ptr + need.sum(dtype=jnp.int32)
        return (heads, ptr), sym

    (_, _), syms = jax.lax.scan(step, (heads, jnp.int32(0)), None, length=rows)
    return syms
