"""Pallas TPU kernel: interleaved-lane rANS decode (32/16 variant).

This is the paper's decode hot-path, re-architected for a vector machine
(DESIGN.md §3.1): L lanes decode one symbol per step in lockstep; the ANS
state vector lives in registers/VMEM; renormalization is branchless —

  * the consume mask is a vector compare (head < 2^16),
  * the words each lane needs are *contiguous and lane-ordered* in the
    stream (proved by the encoder-mirror property), so an exclusive
    prefix-sum over the mask yields each lane's word index — no
    scatter/compaction, one gather per step,
  * the model is a static quantized pmf: three (2^r,) VMEM tables
    (slot->symbol / freq / start) turn Eq. (2)-(3) into gathers + uint32
    multiply-adds.  All arithmetic is 32-bit (head in [2^16, 2^32)) —
    TPUs have no native 64-bit integer datapath.

Grid is 1 program; the step loop is a ``fori_loop`` carrying (heads, ptr).
VMEM: words (W*4) + tables (3 * 2^r * 4) + out (T rows * L * 4); ops.py
bounds W and T so the working set stays a few MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rans_decode_pallas", "LANES"]

LANES = 128


def _decode_kernel(heads_ref, words_ref, sym_t_ref, freq_t_ref, start_t_ref,
                   out_ref, *, rows: int, r: int):
    mask = jnp.uint32((1 << r) - 1)
    low = jnp.uint32(1 << 16)

    def step(t, carry):
        heads, ptr = carry
        cf = heads & mask                                    # (L,) uint32
        sym = jnp.take(sym_t_ref[...], cf.astype(jnp.int32))     # gathers
        f = jnp.take(freq_t_ref[...], cf.astype(jnp.int32)).astype(jnp.uint32)
        c = jnp.take(start_t_ref[...], cf.astype(jnp.int32)).astype(jnp.uint32)
        heads = f * (heads >> jnp.uint32(r)) + cf - c
        need = heads < low
        # exclusive prefix-sum -> per-lane word index within this step's group
        k = jnp.cumsum(need.astype(jnp.int32)) - need.astype(jnp.int32)
        idx = ptr + k
        w = jnp.take(words_ref[...], idx).astype(jnp.uint32)
        heads = jnp.where(need, (heads << jnp.uint32(16)) | w, heads)
        ptr = ptr + jnp.sum(need.astype(jnp.int32))
        pl.store(out_ref, (pl.dslice(t, 1), slice(None)), sym[None, :])
        return heads, ptr

    heads0 = heads_ref[...]
    init = (heads0, jnp.int32(0))
    jax.lax.fori_loop(0, rows, step, init)


def rans_decode_pallas(heads, words, sym_t, freq_t, start_t, rows: int,
                       r: int, interpret: bool = True):
    """heads (L,) u32; words (W,) u32 (16-bit values); tables (2^r,) i32.

    Returns (rows, L) int32 symbols (row-major decode order).
    """
    L = heads.shape[0]
    W = words.shape[0]
    tsz = sym_t.shape[0]
    return pl.pallas_call(
        functools.partial(_decode_kernel, rows=rows, r=r),
        in_specs=[
            pl.BlockSpec((L,), lambda: (0,)),
            pl.BlockSpec((W,), lambda: (0,)),
            pl.BlockSpec((tsz,), lambda: (0,)),
            pl.BlockSpec((tsz,), lambda: (0,)),
            pl.BlockSpec((tsz,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, L), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, L), jnp.int32),
        interpret=interpret,
    )(heads, words, sym_t, freq_t, start_t)
