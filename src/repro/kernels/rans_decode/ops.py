"""Jitted wrapper: pads the word stream and returns (rows, L) symbols.

Encode-side counterpart: ``repro.core.vrans.VRans16Encoder`` with a static
quantized pmf (see ``make_tables``).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import LANES, rans_decode_pallas


def make_tables(freqs: np.ndarray, r: int):
    """freqs (A,) summing to 2^r -> (slot->sym, slot->freq, slot->start)."""
    assert freqs.sum() == (1 << r)
    starts = np.cumsum(freqs) - freqs
    sym_t = np.repeat(np.arange(len(freqs)), freqs).astype(np.int32)
    freq_t = freqs[sym_t].astype(np.int32)
    start_t = starts[sym_t].astype(np.int32)
    return sym_t, freq_t, start_t


@functools.partial(jax.jit, static_argnames=("rows", "r", "interpret"))
def rans_decode(heads, words, sym_t, freq_t, start_t, rows: int, r: int,
                interpret: bool = True):
    """Decode rows*L symbols; heads (L,) u32, words (W,) u16/u32."""
    L = heads.shape[0]
    words = jnp.pad(words.astype(jnp.uint32), (0, L))  # slack for masked gathers
    return rans_decode_pallas(
        heads.astype(jnp.uint32), words,
        sym_t.astype(jnp.int32), freq_t.astype(jnp.int32),
        start_t.astype(jnp.int32), rows, r, interpret=interpret)
