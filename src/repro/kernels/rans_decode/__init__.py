from .ops import make_tables, rans_decode
from .ref import rans_decode_ref

__all__ = ["rans_decode", "rans_decode_ref", "make_tables"]
