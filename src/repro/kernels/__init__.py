# Pallas TPU kernels for the paper's compute hot-spots, each with a jit'd
# wrapper (ops.py) and a pure-jnp oracle (ref.py); validated in interpret
# mode on CPU, targeted at TPU v5e BlockSpec tiling.
