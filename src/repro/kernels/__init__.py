"""repro.kernels — Pallas TPU kernels for the paper's compute hot-spots.

Each kernel package pairs a Pallas implementation (``kernel.py``,
targeted at TPU v5e BlockSpec tiling, validated in interpret mode on
CPU) with a jitted wrapper (``ops.py``) and a pure-jnp oracle
(``ref.py``):

* ``l2_topk``   — blocked squared-L2 distance matrix / top-1 scan.
* ``pq_adc``    — PQ asymmetric-distance (ADC) lookup-table scoring.
* ``seg_topk``  — segmented top-k select: cuts per-query candidate rows
  to their k smallest ``(value, column)`` pairs on device, bit-identical
  between the Pallas kernel and the ``lax.top_k`` fallback, so the scan
  engines never pull a full distance block to the host.
* ``rans_decode`` — interleaved-stream rANS symbol decode.
* ``wt_rank``   — wavelet-tree bitvector rank over packed u32 words.

The scan engines (``repro.ann.scan`` / ``repro.ann.graph_scan``) pick
kernels vs the XLA fallback per call via ``engine=auto|xla|pallas``.
"""

from .l2_topk import l2_dist, l2_dist_ref, l2_top1, l2_top1_ref
from .pq_adc import pq_adc, pq_adc_ref
from .rans_decode import make_tables, rans_decode, rans_decode_ref
from .seg_topk import seg_topk, seg_topk_ref, seg_topk_xla
from .wt_rank import pack_bits_u32, wt_rank, wt_rank_ref

__all__ = [
    "l2_dist", "l2_dist_ref", "l2_top1", "l2_top1_ref",
    "pq_adc", "pq_adc_ref",
    "seg_topk", "seg_topk_xla", "seg_topk_ref",
    "rans_decode", "rans_decode_ref", "make_tables",
    "wt_rank", "wt_rank_ref", "pack_bits_u32",
]
