"""Sequence-parallel (flash-decoding style) attention for sharded KV caches.

When a decode cell shards the KV cache's *sequence* dim over the "model"
axis (granite/qwen decode_32k, all long_500k cells — see
``cache_shardings``), the reference decode attention makes XLA reduce
softmax statistics across shards op-by-op.  This module gives the explicit
shard_map version: each shard computes attention over its local KV slice
plus (max, sum-exp) statistics; one tiny ``psum`` pair combines them —
the flash-decoding two-pass reduction, with bytes O(B·H) instead of
O(B·H·T).

``sp_decode_attention`` is a drop-in for one-token decode given already-
rotated q and the local cache shard; validated against the dense reference
in tests/test_distributed.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import pvary, shard_map

__all__ = ["sp_decode_attention"]


def sp_decode_attention(q, k_shard, v_shard, valid_mask, axis: str = "model"):
    """q (B,1,H,D) replicated over ``axis``; k/v (B,T_local,KV,D) = the
    local sequence shard; valid_mask (B,T_local) marks filled slots.

    Returns (B,1,H,D), numerically identical to attention over the full
    gathered cache (up to fp roundoff).  Call inside shard_map with
    in_specs (P(), P(None, axis, None, None), ..., P(None, axis)).
    """
    B, _, H, D = q.shape
    KV = k_shard.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_shard).astype(jnp.float32)
    s = s / jnp.sqrt(D).astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    s = jnp.where(valid_mask[:, None, None, :], s, neg)
    # local statistics
    m_loc = s.max(axis=-1)                                   # (B,KV,G)
    p = jnp.exp(s - m_loc[..., None])
    l_loc = p.sum(axis=-1)
    o_loc = jnp.einsum("bkgt,btkd->bkgd", p.astype(q.dtype), v_shard)
    # global combine: two scalars per head + one vector — O(B*H*D) bytes
    m_glob = jax.lax.pmax(m_loc, axis)
    scale = jnp.exp(m_loc - m_glob)
    l_glob = jax.lax.psum(l_loc * scale, axis)
    o_glob = jax.lax.psum(o_loc * scale[..., None].astype(o_loc.dtype), axis)
    out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None].astype(o_glob.dtype)
    return out.reshape(B, 1, H, D)


def make_sp_decode(mesh, axis: str = "model"):
    """shard_map wrapper: full-shape (B,1,H,D) q + seq-sharded (B,T,KV,D)."""
    def fn(q, k, v, valid):
        return shard_map(
            lambda q_, k_, v_, m_: sp_decode_attention(q_, k_, v_, m_, axis),
            mesh=mesh,
            in_specs=(P(), P(None, axis, None, None),
                      P(None, axis, None, None), P(None, axis)),
            out_specs=P(),
        )(q, k, v, valid)

    return fn
