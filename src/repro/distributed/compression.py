"""Gradient compression: int8 error-feedback quantization (cross-pod DP).

At 2+ pods the gradient all-reduce crosses the slow inter-pod links; 4x
compression there buys real step time.  Scheme (1-bit-Adam-style, but int8):
per-tensor scale = max|g| / 127, quantize, DEQUANTIZE locally and keep the
residual in an error-feedback accumulator folded into the next step — an
unbiased-in-the-limit estimator that preserves convergence (validated in
tests/test_distributed.py on a real training loss curve).

``compress_tree_int8`` is the stateless variant used inside train_step;
``EFCompressor`` carries the error-feedback state across steps.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_tree_int8", "EFCompressor", "ef_init", "ef_compress"]


def _q8(g: jnp.ndarray) -> jnp.ndarray:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_tree_int8(grads: Any) -> Any:
    """Simulate the int8 all-reduce path: quantize-dequantize each leaf."""
    return jax.tree.map(_q8, grads)


class EFState(NamedTuple):
    residual: Any


def ef_init(params: Any) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress(grads: Any, state: EFState) -> Tuple[Any, EFState]:
    """Error-feedback int8: compress (g + residual), carry the error."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q = _q8(gf)
        return q, gf - q

    pairs = jax.tree.map(one, grads, state.residual)
    comp = jax.tree.map(lambda pr: pr[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda pr: pr[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, EFState(residual=res)


class EFCompressor:
    """Object wrapper for loops that keep python-side state."""

    def __init__(self, params):
        self.state = ef_init(params)

    def __call__(self, grads):
        comp, self.state = ef_compress(grads, self.state)
        return comp
