"""Divisibility-aware logical sharding rules (MaxText-style, DESIGN.md §6).

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod; ``pod`` is an outer data-parallel axis.  All rules degrade
deterministically when a dimension does not divide the axis size — no
config ever fails to shard, it just shards less.

Parameters (leaf-name keyed):
  * 2-D kernels          (in, out)   -> (fsdp="data", tp="model")
  * "second" matrices    (wo, out_proj, lora_b, down)
                          (in, out)  -> (tp="model",  fsdp="data")
  * expert kernels       (E, in, out)-> (tp, fsdp, -) / wo: (tp, -, fsdp)
  * embedding table      (V, d)      -> (tp, fsdp)
  * biases / gains       (d,)        -> (tp) when divisible
Activations:
  * batch -> (pod, data); when batch==1 (long_500k) sequence -> data.
KV caches / recurrent states: pattern-matched on shape (see cache_spec).
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_spec",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "axis_size",
    "dp_axes",
]

_SECOND_MATS = ("wo", "out_proj", "lora_b", "wd", "r")


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _div(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               n_experts: int = 0) -> P:
    tp = axis_size(mesh, "model")
    fsdp = axis_size(mesh, "data")
    leaf = path.split("/")[-2] if path.endswith("kernel") or path.endswith("bias") \
        else path.split("/")[-1]
    is_second = any(leaf == s or leaf.endswith(s) for s in _SECOND_MATS)

    # strip stacked scan dims: leading dims that came from vmap over layers
    # are recognized by rank: rules apply to the trailing "logical" dims.
    def spec_for_logical(lshape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
        nd = len(lshape)
        if nd == 1:
            return ("model",) if _div(lshape[0], tp) else (None,)
        if nd == 2:
            a, b = lshape
            if "embed/table" in path:
                return ("model" if _div(a, tp) else None,
                        "data" if _div(b, fsdp) else None)
            if is_second:
                return ("model" if _div(a, tp) else None,
                        "data" if _div(b, fsdp) else None)
            return ("data" if _div(a, fsdp) else None,
                    "model" if _div(b, tp) else None)
        if nd == 3 and n_experts and lshape[0] == n_experts:
            e = "model" if _div(lshape[0], tp) else None
            if is_second:  # (E, ff, d)
                return (e, None, "data" if _div(lshape[2], fsdp) else None)
            return (e, "data" if _div(lshape[1], fsdp) else None, None)
        if nd == 3:
            return (None,
                    "data" if _div(lshape[1], fsdp) else None,
                    "model" if _div(lshape[2], tp) else None)
        # >=4D conv-ish / unusual: shard the last divisible dim on model
        out = [None] * nd
        for i in range(nd - 1, -1, -1):
            if _div(lshape[i], tp):
                out[i] = "model"
                break
        return tuple(out)

    # count leading stacked dims: all dims before the final 1-3 logical dims.
    # Heuristic: norms/gains are (L.., d); kernels are (L.., in, out) or
    # (L.., E, in, out).  We treat trailing `k` dims as logical where k is
    # 3 if an expert dim matches, else min(2, rank), except pure vectors.
    nd = len(shape)
    if nd == 0:
        return P()
    k = 1
    if nd >= 3 and n_experts and shape[-3] == n_experts:
        k = 3
    elif nd >= 2:
        k = 2
    # vectors stacked over layers: (L, d) — d is the logical dim
    if leaf in ("scale", "bias", "A_log", "D", "dt_bias") or (
        nd >= 1 and k == 2 and path.endswith(("scale", "bias"))
    ):
        k = 1
    if k > nd:
        k = nd
    logical = spec_for_logical(shape[nd - k:])
    return P(*([None] * (nd - k)), *logical)


def param_shardings(params_shapes: Any, mesh: Mesh, n_experts: int = 0) -> Any:
    """Tree of NamedShardings matching a tree of ShapeDtypeStructs."""
    def f(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, n_experts)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, params_shapes)


def batch_shardings(batch_specs: Any, mesh: Mesh) -> Any:
    """Input shardings for train/prefill batches (dict of arrays)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))

    def f(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name.endswith("positions") and len(shape) == 3:  # (3, B, S)
            b, s = shape[1], shape[2]
            if _div(b, dp_size):
                return NamedSharding(mesh, P(None, dp, None))
            return NamedSharding(mesh, P(None, None, dp if _div(s, dp_size) else None))
        if len(shape) >= 2:
            b, s = shape[0], shape[1]
            rest = [None] * (len(shape) - 2)
            if _div(b, dp_size):
                return NamedSharding(mesh, P(dp, None, *rest))
            if _div(s, dp_size):
                return NamedSharding(mesh, P(None, dp, *rest))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(f, batch_specs)


def cache_shardings(cache_shapes: Any, mesh: Mesh, global_batch: int,
                    n_kv_heads: int) -> Any:
    """Shardings for KV caches / recurrent states (shape pattern-matched).

    KV leaves (..., B, T, KV, hd): batch->dp when divisible; KV->model when
    divisible else T->model (sequence-sharded decode); long-context batch=1
    shards T over (data[, pod]) too.
    """
    tp = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))

    def f(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        spec: list = [None] * nd
        # locate the batch dim: first dim equal to global_batch
        b_idx = next((i for i, d in enumerate(shape) if d == global_batch), None)
        if nd >= 4 and shape[-2] == n_kv_heads:
            t_idx, kv_idx = nd - 3, nd - 2
            if b_idx is not None and b_idx < t_idx and _div(shape[b_idx], dp_size):
                spec[b_idx] = dp
                if _div(n_kv_heads, tp):
                    spec[kv_idx] = "model"
                elif _div(shape[t_idx], tp):
                    spec[t_idx] = "model"
            else:
                # batch unshardable (long_500k): shard T over everything
                if _div(shape[t_idx], dp_size * tp):
                    spec[t_idx] = (*dp, "model")
                elif _div(shape[t_idx], dp_size):
                    spec[t_idx] = dp
            return NamedSharding(mesh, P(*spec))
        # recurrent states / conv windows: batch->dp; else last divisible->model
        if b_idx is not None and _div(shape[b_idx], dp_size):
            spec[b_idx] = dp
        for i in range(nd - 1, -1, -1):
            if spec[i] is None and i != b_idx and _div(shape[i], tp):
                spec[i] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, cache_shapes)
