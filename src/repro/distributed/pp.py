"""GPipe-style pipeline parallelism over a mesh axis (shard_map +
collective_permute).

The ``pod`` axis can be re-purposed as a pipeline axis: each pod holds a
contiguous stage of layers; microbatches rotate through stages with
``jax.lax.ppermute``.  This is the standard 1F1B-less GPipe schedule —
bubble fraction (S-1)/(S-1+M) — implemented as a self-contained transform
so any per-stage function can be pipelined.  Demonstrated in
tests/test_distributed.py with a 4-stage MLP on 4 host devices; the
production meshes use pod=2 stages.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import pvary, shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable, n_stages: int, n_micro: int,
                   mesh, axis: str = "pod"):
    """Returns f(stage_params, x) running stage_fn pipelined over ``axis``.

    stage_params: pytree whose leaves lead with the stage dim (n_stages, ...),
    sharded one-stage-per-device along ``axis``.
    x: (n_micro, micro_batch, ...) microbatched input, replicated.
    Output: (n_micro, micro_batch, ...) after all stages.
    """

    def pipelined(stage_params, x):
        def per_stage(params, xs):
            # params: this stage's slice (leading dim 1); xs: all microbatches
            params = jax.tree.map(lambda a: a[0], params)
            stage_id = jax.lax.axis_index(axis)
            n_steps = n_stages + n_micro - 1
            buf = xs  # (n_micro, mb, ...)
            # carries are device-varying (each stage holds different data):
            # mark them as such for shard_map's vma type system
            carry = pvary(jnp.zeros_like(xs[0]), (axis,))
            outs = pvary(jnp.zeros_like(xs), (axis,))

            def step(t, state):
                carry, outs = state
                # stage 0 injects microbatch t; others take the permuted carry
                inject = jax.lax.dynamic_index_in_dim(
                    buf, jnp.clip(t, 0, n_micro - 1), keepdims=False)
                inp = jnp.where(stage_id == 0, pvary(inject, (axis,)),
                                carry)
                active = (t >= stage_id) & (t - stage_id < n_micro)
                out = jnp.where(active, stage_fn(params, inp), inp)
                # last stage records its finished microbatch
                done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
                record = (stage_id == n_stages - 1) & (t >= n_stages - 1)
                updated = jax.lax.dynamic_update_index_in_dim(
                    outs, out, done_idx, 0)
                outs = jnp.where(record, updated, outs)
                # rotate stage outputs forward
                carry = jax.lax.ppermute(
                    out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
                return carry, outs

            carry, outs = jax.lax.fori_loop(0, n_steps, step, (carry, outs))
            # all-gather nothing: outs live on the last stage; broadcast them
            outs = jax.lax.psum(
                jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
                axis)
            return outs

        return shard_map(
            per_stage, mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
        )(stage_params, x)

    return pipelined
