"""jax API-drift shims for the distribution layer.

The repo targets the current jax surface (``jax.shard_map``,
``jax.lax.pvary``, ``jax.set_mesh``); older installs only have the
``jax.experimental.shard_map`` spelling and no varying-manual-axes (vma)
type system.  These wrappers pick whichever exists so the same code runs
on both (mesh-side shims live in repro.launch.mesh).
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "pvary"]


def shard_map(f, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    # the old replication checker predates pvary-annotated carries; the
    # callers' specs are already explicit, so skip it
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pvary(x, axes):
    """Mark ``x`` device-varying over ``axes`` (identity on old jax)."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axes)
    return x
