"""WebGraph-style offline graph codec — the Zuckerli baseline stand-in.

Full Zuckerli [62] is a large C++ codebase; per DESIGN.md §9 we implement
the WebGraph [5,6] scheme it builds on, with Zuckerli's two headline
improvements approximated: (1) the block/residual structure is
entropy-coded with ANS instead of instantaneous codes, (2) runs of
consecutive integers are run-length encoded.  Per node, the (sorted)
friend list is encoded as:

  * reference selection: try the previous ``W`` nodes; pick the one whose
    list overlaps most; encode the delta (0 = no reference);
  * copy-blocks: the reference list is partitioned into alternating
    copied/skipped blocks; block lengths are entropy-coded;
  * residuals: remaining targets as gap-coded integers (zeta-like bucket +
    uniform refinement), intervals of consecutive ints run-length coded.

This is labeled ``zuckerli-lite`` in benchmark tables.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .ans import StreamANS

__all__ = ["webgraph_encode", "webgraph_decode"]

_WINDOW = 7
_BUCKET_R = 8    # bucket pmf precision

# static decreasing pmf over bucket = bit_length(v) in [0, 32]
_BF = np.maximum(1, (1 << 6) >> (np.arange(33) // 2)).astype(np.int64)
_BF[0] += (1 << _BUCKET_R) - int(_BF.sum())
assert _BF.sum() == (1 << _BUCKET_R) and (_BF > 0).all()
_BC = np.cumsum(_BF) - _BF
_BSLOT = np.repeat(np.arange(33), _BF)


def _push_gamma(ans: StreamANS, v: int) -> None:
    """Entropy-coded Elias-gamma-like: bucket = bit_length, then uniform."""
    b = int(v).bit_length()
    if b > 1:
        # v in [2^(b-1), 2^b): encode low b-1 bits first (decoded last)
        ans.push_uniform_pow2(v - (1 << (b - 1)), b - 1)
    ans.push(int(_BC[b]), int(_BF[b]), _BUCKET_R)


def _pop_gamma(ans: StreamANS) -> int:
    cf = ans.pop_cf(_BUCKET_R)
    b = int(_BSLOT[cf])
    ans.pop_advance(int(_BC[b]), int(_BF[b]), _BUCKET_R)
    if b == 0:
        return 0
    if b == 1:
        return 1
    low = ans.pop_uniform_pow2(b - 1)
    return (1 << (b - 1)) + low


def webgraph_encode(adj: Sequence[np.ndarray], n_vertices: int) -> StreamANS:
    """Encode adjacency lists (target ids per node, any order)."""
    ans = StreamANS()
    sorted_adj = [np.sort(np.asarray(a, dtype=np.int64)) for a in adj]
    # encode nodes in reverse so decode streams forward
    for i in range(len(sorted_adj) - 1, -1, -1):
        _encode_node(ans, sorted_adj, i)
    return ans


def _best_reference(sorted_adj, i: int) -> int:
    best, best_overlap = 0, 0
    mine = set(int(x) for x in sorted_adj[i])
    if not mine:
        return 0
    for d in range(1, min(_WINDOW, i) + 1):
        ref = sorted_adj[i - d]
        overlap = len(mine.intersection(int(x) for x in ref))
        if overlap > best_overlap:
            best, best_overlap = d, overlap
    return best


def _encode_node(ans: StreamANS, sorted_adj, i: int) -> None:
    """Pushes node i's description in reverse of decode order."""
    mine = sorted_adj[i]
    ref_delta = _best_reference(sorted_adj, i)
    ops: List = []  # (kind, value) in DECODE order
    ops.append(("gamma", len(mine)))
    ops.append(("gamma", ref_delta))
    copied = np.zeros(0, dtype=np.int64)
    if ref_delta:
        ref = sorted_adj[i - ref_delta]
        inref = np.isin(ref, mine)
        # alternating block lengths starting with a copied block
        blocks: List[int] = []
        cur, run = True, 0
        for b in inref:
            if bool(b) == cur:
                run += 1
            else:
                blocks.append(run)
                cur, run = not cur, 1
        blocks.append(run)
        # (if ref[0] is not copied the loop already emitted a leading 0 block)
        ops.append(("gamma", len(blocks)))
        for b in blocks:
            ops.append(("gamma", b))
        copied = ref[inref]
    residual = np.setdiff1d(mine, copied, assume_unique=False)
    # interval run-lengths within residuals
    k = 0
    rops: List = []
    nres = len(residual)
    prev = -1
    idx = 0
    while idx < nres:
        run = 1
        while idx + run < nres and residual[idx + run] == residual[idx] + run:
            run += 1
        gap = int(residual[idx]) - prev - 1
        rops.append(("gamma", gap))
        rops.append(("gamma", run - 1))
        prev = int(residual[idx]) + run - 1
        idx += run
        k += 1
    ops.append(("gamma", k))
    ops.extend(rops)
    for kind, v in reversed(ops):
        _push_gamma(ans, int(v))


def webgraph_decode(ans: StreamANS, n_nodes: int, n_vertices: int) -> List[np.ndarray]:
    out: List[np.ndarray] = []
    for i in range(n_nodes):
        deg = _pop_gamma(ans)
        ref_delta = _pop_gamma(ans)
        copied = np.zeros(0, dtype=np.int64)
        if ref_delta:
            ref = out[i - ref_delta]
            nblocks = _pop_gamma(ans)
            blocks = [_pop_gamma(ans) for _ in range(nblocks)]
            mask = np.zeros(len(ref), dtype=bool)
            pos, take = 0, True
            for b in blocks:
                if take:
                    mask[pos : pos + b] = True
                pos += b
                take = not take
            copied = ref[mask]
        k = _pop_gamma(ans)
        residual = []
        prev = -1
        for _ in range(k):
            gap = _pop_gamma(ans)
            run = _pop_gamma(ans) + 1
            start = prev + 1 + gap
            residual.extend(range(start, start + run))
            prev = start + run - 1
        merged = np.sort(np.concatenate([copied, np.asarray(residual, np.int64)]))
        assert len(merged) == deg, "webgraph decode inconsistency"
        out.append(merged)
    return out
