"""Pluggable id-list codec registry — the paper's Table 1/2 codec matrix.

Every codec exposes the same small interface over a *set of unique ids*
drawn from ``[universe)`` (one inverted list / one friend list):

    blob = codec.encode(ids, universe)
    ids' = codec.decode(blob, universe)       # sorted ascending
    bits = codec.size_bits(blob)              # paper-comparable payload

Codecs:
    unc64 / unc32 — FAISS defaults (64/32-bit machine words)      [paper Unc.]
    compact       — ceil(log2 N) bits per id                      [paper Comp.]
    ef            — Elias-Fano                                    [paper EF]
    roc           — Random Order Coding, exact ANS                [paper ROC]
    gap_ans       — sorted-gap + interleaved-lane rANS (TPU path) [beyond paper]

The wavelet tree is not in this registry because it is a *joint* structure
over all clusters (see repro.core.wavelet_tree / repro.ann.ivf).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict

import numpy as np

from .ans import BigANS
from .elias_fano import EliasFano
from .gap_ans import GapAnsCodec
from .roc import roc_pop_set, roc_push_set

__all__ = ["get_codec", "CODEC_NAMES", "IdCodec"]


class IdCodec:
    """Codec contract (codified by tests/test_codec_edges.py):

    * ``encode`` accepts any array of unique ids from ``[universe)`` —
      including the empty set, a single id, and the full universe — in any
      order; ``decode`` returns them sorted ascending as int64.
    * ``size_bits(blob) >= 0``, and is 0 only for the empty set (modulo a
      codec's fixed per-list header).
    * ``gather(blob, offsets)`` returns the ids at the given sorted-order
      positions for random-access codecs (EF/compact/uncompressed) and
      ``None`` for stream codecs (ROC/gap-ANS), which the caller resolves
      by decoding the whole list once (see repro.ann.scan).
    """

    name: str = "base"

    def encode(self, ids: np.ndarray, universe: int):
        raise NotImplementedError

    def decode(self, blob, universe: int) -> np.ndarray:
        raise NotImplementedError

    def size_bits(self, blob) -> int:
        raise NotImplementedError

    def gather(self, blob, offsets: np.ndarray):
        """Random access: ids at ``offsets`` (positions in sorted order).

        Returns ``None`` when the codec only supports full decode.
        """
        return None


@dataclasses.dataclass
class RawCodec(IdCodec):
    width: int = 64

    @property
    def name(self) -> str:
        return f"unc{self.width}"

    def encode(self, ids, universe):
        return {"ids": np.sort(np.asarray(ids, dtype=np.int64)), "n": len(ids)}

    def decode(self, blob, universe):
        return blob["ids"]

    def size_bits(self, blob):
        return self.width * blob["n"]

    def gather(self, blob, offsets):
        return blob["ids"][np.asarray(offsets, dtype=np.int64)]


class CompactCodec(IdCodec):
    name = "compact"

    def encode(self, ids, universe):
        return {
            "ids": np.sort(np.asarray(ids, dtype=np.int64)),
            "n": len(ids),
            "w": max(1, math.ceil(math.log2(max(2, universe)))),
        }

    def decode(self, blob, universe):
        return blob["ids"]

    def size_bits(self, blob):
        return blob["w"] * blob["n"]

    def gather(self, blob, offsets):
        return blob["ids"][np.asarray(offsets, dtype=np.int64)]


class EFCodec(IdCodec):
    name = "ef"

    def encode(self, ids, universe):
        return EliasFano.encode(np.asarray(ids), universe)

    def decode(self, blob, universe):
        return blob.decode()

    def size_bits(self, blob):
        return blob.size_bits

    def gather(self, blob, offsets):
        return np.array([blob.access(int(o)) for o in np.asarray(offsets)],
                        dtype=np.int64)


class ROCCodec(IdCodec):
    name = "roc"

    def encode(self, ids, universe):
        ans = BigANS()
        roc_push_set(ans, np.asarray(ids), universe)
        return {"state": ans.tobytes(), "n": len(ids)}

    def decode(self, blob, universe):
        ans = BigANS.frombytes(blob["state"])
        return roc_pop_set(ans, blob["n"], universe)

    def size_bits(self, blob):
        return len(blob["state"]) * 8 - _leading_zero_bits(blob["state"])


def _leading_zero_bits(raw: bytes) -> int:
    """Exact bit count: whole bytes minus the top byte's unused bits."""
    if not raw:
        return 0
    top = raw[-1]
    return 8 - top.bit_length() if top else 8


class GapCodec(IdCodec):
    name = "gap_ans"

    def __init__(self, lanes: int = 0):   # 0 = scale lanes with cluster size
        self._impl = GapAnsCodec(lanes=lanes)

    def encode(self, ids, universe):
        return self._impl.encode(np.asarray(ids), universe)

    def decode(self, blob, universe):
        return self._impl.decode(blob, universe)

    def size_bits(self, blob):
        return self._impl.size_bits(blob)


_REGISTRY: Dict[str, Callable[[], IdCodec]] = {
    "unc64": lambda: RawCodec(64),
    "unc32": lambda: RawCodec(32),
    "compact": CompactCodec,
    "ef": EFCodec,
    "roc": ROCCodec,
    "gap_ans": GapCodec,
}

CODEC_NAMES = tuple(_REGISTRY)


def get_codec(name: str) -> IdCodec:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(f"unknown id codec {name!r}; options: {CODEC_NAMES}")
