"""Random Edge Coding (REC) — offline whole-graph compression (paper §3.2/§4.3).

A graph's edge list is an order-invariant *multiset* of vertex pairs; REC
collects the full ``log E!`` of edge-order freedom (much larger than the
per-node ``sum_i log m_i!`` of the online setting) by bits-back coding over
a latent edge permutation, with the two endpoints of each edge coded under a
vertex model.

Decode (forward)::

    for i = 1..E:
        u = pop_vertex(model); model.observe(u)
        v = pop_vertex(model); model.observe(v)
        insert (u, v) at rank j of the sorted decoded-edge list
        push_uniform(j, i)                     # bits-back

Encode is the exact mirror run backwards (Fenwick over the canonically
sorted edge list for rank selection; model un-observes before pushing).

Vertex models:
  * ``polya`` — Pólya urn, freq(v) = count(v) + 1, the adaptive model of
    [51] with b=0 bias as the paper uses for directed NSG graphs.  Coded
    with the *exact* ``BigANS`` (arbitrary totals); state size grows with
    the graph, so this path is quadratic-ish and meant for the paper-rate
    measurement at moderate E.
  * ``degree`` — a static model proportional to final vertex degrees
    (quantized to 2^r), streamed with ``StreamANS`` in O(E log N); the
    degree table is counted in the reported size.  This is the fast path
    (and the TPU-facing one — static tables only; DESIGN.md §3.5).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .ans import BigANS, StreamANS
from .fenwick import Fenwick

__all__ = ["rec_encode", "rec_decode", "RECResult"]


@dataclasses.dataclass
class RECResult:
    payload_bits: int
    aux_bits: int          # degree table for the static model, else 0
    model: str
    state: object          # BigANS | StreamANS
    aux: object = None

    @property
    def total_bits(self) -> int:
        return self.payload_bits + self.aux_bits


def _canonical_edges(edges: np.ndarray) -> np.ndarray:
    """Sort edges lexicographically (the canonical order for rank coding)."""
    edges = np.asarray(edges, dtype=np.int64)
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    return edges[order]


# ---------------------------------------------------------------------------
# Pólya-urn model with exact coding
# ---------------------------------------------------------------------------

def _urn_push(ans: BigANS, fw: Fenwick, v: int) -> None:
    """Push vertex v under freq(v) = count(v) + 1 (counts held in fw - 1)."""
    freq = fw.get(v)
    cum = fw.cum(v)
    ans.push_pmf(cum, freq, fw.total)


def _urn_pop(ans: BigANS, fw: Fenwick) -> int:
    cf = ans.pop_cf(fw.total)
    v = fw.find(cf)
    ans.pop_advance(fw.cum(v), fw.get(v), fw.total)
    return v


def rec_encode(edges: np.ndarray, n_vertices: int, model: str = "polya") -> RECResult:
    """Encode a directed edge list (E, 2). See module docstring."""
    edges = _canonical_edges(edges)
    E = edges.shape[0]
    if model == "polya":
        return _rec_encode_polya(edges, n_vertices, E)
    if model == "degree":
        return _rec_encode_degree(edges, n_vertices, E)
    raise ValueError(f"unknown REC model {model!r}")


def rec_decode(res: RECResult, n_vertices: int, n_edges: int) -> np.ndarray:
    if res.model == "polya":
        return _rec_decode_polya(res.state, n_vertices, n_edges)
    return _rec_decode_degree(res.state, res.aux, n_vertices, n_edges)


def _rec_encode_polya(edges: np.ndarray, N: int, E: int) -> RECResult:
    ans = BigANS()
    # final counts: every endpoint observed once; urn freq = count + 1
    weights = np.bincount(edges.reshape(-1), minlength=N) + 1
    fw = Fenwick([int(w) for w in weights])
    fw_edges = Fenwick.ones(E)
    elist = edges  # canonical order; fw_edges masks removals
    for i in range(E, 0, -1):
        j = ans.pop_uniform(i)
        pos = fw_edges.find(j)
        fw_edges.add(pos, -1)
        u, v = int(elist[pos, 0]), int(elist[pos, 1])
        # mirror of decode (pop u, observe, pop v, observe): un-observe v,
        # push v, un-observe u, push u.
        fw.add(v, -1)
        _urn_push(ans, fw, v)
        fw.add(u, -1)
        _urn_push(ans, fw, u)
    return RECResult(payload_bits=ans.bits, aux_bits=0, model="polya", state=ans)


def _rec_decode_polya(ans: BigANS, N: int, E: int) -> np.ndarray:
    fw = Fenwick.ones(N)  # counts 0 + 1
    decoded: List[Tuple[int, int]] = []
    import bisect

    for i in range(1, E + 1):
        u = _urn_pop(ans, fw)
        fw.add(u, 1)
        v = _urn_pop(ans, fw)
        fw.add(v, 1)
        e = (u, v)
        j = bisect.bisect_left(decoded, e)
        decoded.insert(j, e)
        ans.push_uniform(j, i)
    return np.asarray(decoded, dtype=np.int64)


# ---------------------------------------------------------------------------
# Static degree model with streaming coding
# ---------------------------------------------------------------------------

_DEG_R = 20  # pmf precision

# "The initial state must be filled with a few random bits" (paper §3.2):
# the degree path interleaves bits-back rank pops with vertex pushes, and
# the first pops draw on a fresh state.  A fixed 63-bit seed provides the
# cushion; its ~64 bits are a one-time overhead counted in payload_bits.
_SEED = (1 << 63) | 0x5DEECE66D1234567


def _degree_table(degrees: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize freq(v) ∝ degree(v) to total 2^_DEG_R (zeros stay zero)."""
    total = 1 << _DEG_R
    deg = degrees.astype(np.int64)
    pos = deg > 0
    npos = int(pos.sum())
    if npos == 0:
        raise ValueError("graph has no edges")
    scaled = np.zeros_like(deg)
    scaled[pos] = np.maximum(1, (deg[pos] * (total - npos)) // int(deg.sum()))
    # exact fixup on the largest entry
    scaled[np.argmax(scaled)] += total - int(scaled.sum())
    cums = np.concatenate([[0], np.cumsum(scaled)[:-1]])
    return scaled, cums


def _rec_encode_degree(edges: np.ndarray, N: int, E: int) -> RECResult:
    degrees = np.bincount(edges.reshape(-1), minlength=N)
    freqs, cums = _degree_table(degrees)
    ans = StreamANS(head=_SEED)
    fw_edges = Fenwick.ones(E)
    # Pow2-truncated bits-back: sample rank j < 2^floor(log2 i) <= i.  The
    # decoded-set-equals-remaining-set identity makes this consistent on
    # both sides; the saving is sum floor(log2 i) ~= log E! - 0.5E bits
    # (the exact-rate reference is the polya path).
    for i in range(E, 0, -1):
        r = int(i).bit_length() - 1  # floor(log2 i)
        j = ans.pop_uniform_pow2(r) if r > 0 else 0
        pos = fw_edges.find(j)
        fw_edges.add(pos, -1)
        u, v = int(edges[pos, 0]), int(edges[pos, 1])
        # decode order per edge: pop u, pop v, push rank -> mirror here.
        ans.push(int(cums[v]), int(freqs[v]), _DEG_R)
        ans.push(int(cums[u]), int(freqs[u]), _DEG_R)
    return RECResult(
        payload_bits=ans.bits,
        aux_bits=_degree_table_bits(degrees),
        model="degree",
        state=ans,
        aux=(freqs, cums),
    )


def _degree_table_bits(degrees: np.ndarray) -> int:
    """Cost of shipping the degree table: ANS-coded counts (entropy + eps)."""
    vals, counts = np.unique(degrees, return_counts=True)
    p = counts / counts.sum()
    h = float(-(p * np.log2(p)).sum())
    # per-vertex entropy of the degree value + the (value -> freq) dictionary
    return int(np.ceil(h * len(degrees))) + 64 * len(vals)


def _rec_decode_degree(ans: StreamANS, aux, N: int, E: int) -> np.ndarray:
    from .sortedlist import SortedList

    freqs, cums = aux
    # cf -> vertex via binary search on the cumulative table (O(log N))
    cum_incl = np.cumsum(freqs)

    def pop_vertex() -> int:
        cf = ans.pop_cf(_DEG_R)
        v = int(np.searchsorted(cum_incl, cf, side="right"))
        ans.pop_advance(int(cums[v]), int(freqs[v]), _DEG_R)
        return v

    decoded = SortedList()
    for i in range(1, E + 1):
        u = pop_vertex()
        v = pop_vertex()
        j = decoded.insert(u * N + v)  # lexicographic key
        r = int(i).bit_length() - 1
        if r > 0:
            ans.push_uniform_pow2(j, r)
    keys = np.asarray(decoded.to_list(), dtype=np.int64)
    return np.stack([keys // N, keys % N], axis=1)
