"""Gap-ANS: the TPU-native set codec (beyond-paper optimization).

Exact ROC needs sequential order statistics (Fenwick pointer-chasing) — fine
on a CPU, hostile to a TPU.  The paper itself notes (§4) that *a sorted
sequence is informationally equivalent to a set*.  We exploit that: sort the
ids (TPUs sort well), delta-encode the gaps, and entropy-code the gaps with
the vectorized interleaved-lane rANS under a per-cluster Rice/geometric
model:

    ids sorted ascending;  g_0 = ids[0];  g_i = ids[i] - ids[i-1] - 1
    k   = Rice parameter  ~ log2(mean gap)          (per cluster, 5-bit header)
    q_i = g_i >> k   coded with a static geometric table (escape for tails)
    rem = g_i & (2^k - 1)  coded uniform (k bits, split into <=12-bit pushes)

Decode is fully parallel: lanes decode round-robin symbols in lockstep and a
prefix sum over gaps reconstructs the ids (``repro.kernels.rans_decode`` is
the Pallas realization — the same 32/16 coder).

Perf-iteration note (EXPERIMENTS.md §Perf): v1 used the 64/32 coder with a
fixed 64 lanes; the 64-bit lane heads cost ``64*64/n`` bits/id — 4.2 bpe at
n=977 and 10+ bpe for small clusters, wiping out the compression.  v2 (this
file) uses 32-bit heads (the 32/16 coder — also the only one a TPU can run
natively) and scales lanes with the cluster size, capping head overhead at
~1 bit/id while keeping wide decode parallelism for large clusters.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .vrans import VRans16Decoder, VRans16Encoder

__all__ = ["GapAnsCodec", "encode_gaps", "decode_gaps", "lanes_for"]

_Q_PRECISION = 12          # 2^12 total for the quotient table
_Q_SYMBOLS = 24            # geometric table size; last slot = escape
_ESCAPE = _Q_SYMBOLS - 1
_OVERFLOW_BITS = 24        # uniform bits for escaped quotients (2 pushes)
_CHUNK = 12                # max bits per uniform push (r <= 16 for 32/16)
_MAX_K = 30


def _quotient_table() -> Tuple[np.ndarray, np.ndarray]:
    """Static geometric pmf over Rice quotients, quantized to 2^12."""
    total = 1 << _Q_PRECISION
    freqs = np.maximum(1, total >> (np.arange(_Q_SYMBOLS) + 1)).astype(np.int64)
    slack = total - int(freqs.sum())
    freqs[_ESCAPE if slack >= 0 else 0] += slack
    assert freqs.sum() == total and (freqs > 0).all()
    cums = np.concatenate([[0], np.cumsum(freqs)[:-1]]).astype(np.int64)
    return freqs, cums


_QF, _QC = _quotient_table()
_SLOT2SYM = np.repeat(np.arange(_Q_SYMBOLS), _QF).astype(np.int64)


def lanes_for(n: int) -> int:
    """Lane count scaling: ~0.5 bit/id of head overhead, wide when it pays.

    Perf-iteration v3 (EXPERIMENTS.md §Perf): n//32 -> n//64 halves the
    per-cluster head overhead for mid-size clusters at half the decode
    parallelism — measured net win at IVF cluster sizes (~1k ids).
    """
    return int(max(1, min(64, n // 64)))


def _rice_k(n: int, universe: int) -> int:
    if n <= 0:
        return 0
    mean_gap = max(0, universe - n) / (n + 1)
    k = int(np.floor(np.log2(mean_gap + 1.0))) if mean_gap > 0 else 0
    return max(0, min(k, _MAX_K))


def _best_k(gaps: np.ndarray, universe: int) -> int:
    """Per-cluster Rice parameter by exact cost search around the estimate.

    Perf-iteration v3: the closed-form k underestimates by ~0.3 bit/id when
    the gap distribution is over-dispersed (k-means clusters); an exact
    3-candidate sweep over the static table cost fixes it for O(n) work.
    """
    n = len(gaps)
    k0 = _rice_k(n, universe)
    logp = -np.log2(_QF / _QF.sum())
    best_k, best_cost = k0, None
    for k in range(max(0, k0 - 1), min(_MAX_K, k0 + 2) + 1):
        q = gaps >> k
        qs = np.minimum(q, _ESCAPE)
        cost = n * k + float(logp[qs].sum()) + _OVERFLOW_BITS * int((q >= _ESCAPE).sum())
        if best_cost is None or cost < best_cost:
            best_k, best_cost = k, cost
    return best_k


def _push_uniform_wide(enc: VRans16Encoder, vals: np.ndarray, bits: int,
                       mask: np.ndarray) -> None:
    """Uniform push of ``bits``-wide values as <=_CHUNK-bit pieces.

    Pieces are pushed high-chunk-first so decode pops low-chunk-first
    (encode order is the reverse of decode order).
    """
    done = 0
    pieces = []
    while done < bits:
        w = min(_CHUNK, bits - done)
        pieces.append(((vals >> done) & ((1 << w) - 1), w))
        done += w
    for piece, w in reversed(pieces):
        enc.push_uniform(piece, w, mask=mask)


def _pop_uniform_wide(dec: VRans16Decoder, bits: int, mask: np.ndarray,
                      lanes: int) -> np.ndarray:
    out = np.zeros(lanes, dtype=np.int64)
    done = 0
    while done < bits:
        w = min(_CHUNK, bits - done)
        piece = dec.pop_uniform(w, mask=mask)
        out |= piece.astype(np.int64) << done
        done += w
    return out


def encode_gaps(
    ids: np.ndarray, universe: int, lanes: int = 0
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Encode a set of unique ids from [universe). Returns (heads, words, k)."""
    ids = np.sort(np.asarray(ids, dtype=np.int64))
    n = int(ids.size)
    lanes = lanes or lanes_for(n)
    k = _rice_k(n, universe)
    if n == 0:
        enc = VRans16Encoder(lanes)
        heads, words = enc.finalize()
        return heads, words, k
    gaps = np.empty(n, dtype=np.int64)
    gaps[0] = ids[0]
    gaps[1:] = ids[1:] - ids[:-1] - 1
    if gaps.min() < 0:
        raise ValueError("ids must be unique and within range")
    k = _best_k(gaps, universe)
    q = gaps >> k
    rem = gaps & ((1 << k) - 1)
    qs = np.minimum(q, _ESCAPE)
    over = q - _ESCAPE
    if np.any(over >= (1 << _OVERFLOW_BITS)):
        raise ValueError("gap overflow beyond escape range")

    rows = -(-n // lanes)
    pad = rows * lanes - n

    def laneify(a: np.ndarray) -> np.ndarray:
        return np.concatenate([a, np.zeros(pad, a.dtype)]).reshape(rows, lanes)

    qs_m, over_m, rem_m = laneify(qs), laneify(over), laneify(rem)
    valid = laneify(np.ones(n, dtype=bool))
    esc_m = laneify(q >= _ESCAPE) & valid

    enc = VRans16Encoder(lanes)
    # push in reverse decode order; decode order per row: q, [overflow], rem.
    for t in range(rows - 1, -1, -1):
        if k > 0:
            _push_uniform_wide(enc, rem_m[t], k, valid[t])
        if esc_m[t].any():
            _push_uniform_wide(enc, over_m[t], _OVERFLOW_BITS, esc_m[t])
        enc.push(_QC[qs_m[t]], _QF[qs_m[t]], _Q_PRECISION, mask=valid[t])
    heads, words = enc.finalize()
    return heads, words, k


def decode_gaps(
    heads: np.ndarray, words: np.ndarray, k: int, n: int, lanes: int = 0
) -> np.ndarray:
    """Decode a set encoded by :func:`encode_gaps`; returns sorted ids."""
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    lanes = lanes or lanes_for(n)
    dec = VRans16Decoder(heads, words)
    rows = -(-n // lanes)
    gaps = np.zeros((rows, lanes), dtype=np.int64)
    flat_valid = np.zeros(rows * lanes, dtype=bool)
    flat_valid[:n] = True
    valid = flat_valid.reshape(rows, lanes)
    for t in range(rows):
        cf = dec.peek_cf(_Q_PRECISION)
        q = _SLOT2SYM[cf]
        dec.advance(_QC[q], _QF[q], _Q_PRECISION, mask=valid[t])
        q = np.where(valid[t], q, 0)
        esc = (q == _ESCAPE) & valid[t]
        if esc.any():
            over = _pop_uniform_wide(dec, _OVERFLOW_BITS, esc, lanes)
            q = q + np.where(esc, over, 0)
        rem = (_pop_uniform_wide(dec, k, valid[t], lanes)
               if k > 0 else np.zeros(lanes, np.int64))
        gaps[t] = (q.astype(np.int64) << k) | np.where(valid[t], rem, 0)
    flat = gaps.reshape(-1)[:n]
    return np.cumsum(flat + 1) - 1


@dataclasses.dataclass
class GapAnsCodec:
    """Set codec facade used by the index layer (see repro.core.codecs).

    ``lanes=0`` (default) scales lanes with cluster size.
    """

    lanes: int = 0

    def encode(self, ids: np.ndarray, universe: int):
        n = int(len(ids))
        lanes = self.lanes or lanes_for(n)
        heads, words, k = encode_gaps(ids, universe, lanes)
        return {"heads": heads, "words": words, "k": k, "n": n}

    def decode(self, blob, universe: int) -> np.ndarray:
        lanes = self.lanes or lanes_for(blob["n"])
        return decode_gaps(
            blob["heads"], blob["words"], blob["k"], blob["n"], lanes
        )

    def size_bits(self, blob) -> int:
        # 32-bit lane heads + 16-bit words + 5-bit Rice header
        return (32 * int(blob["heads"].shape[0])
                + 16 * int(blob["words"].shape[0]) + 5)
