"""Elias-Fano coding of monotone id sequences (paper baseline, Appendix A.1).

For n sorted ids with universe u: the low ``l = max(0, floor(log2(u/n)))``
bits are concatenated verbatim; the high parts ``ids >> l`` are coded in
unary into a bitvector of ``n + (u >> l) + 1`` bits (bit ``(ids[i] >> l) + i``
is set).  Total ~= ``n * (2 + log2(u/n))`` bits — within 0.56 bits/id of the
set bound for large n.  ``access(i)`` needs ``select1(i)`` on the high
bitvector; we keep a sampled select index (counted in the overhead figure,
excluded from the paper-comparable ``size_bits`` like the paper does:
"the sum of bits in both bit streams ... without overheads").
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bitvec import BitVector, pack_lowbits, unpack_lowbits

__all__ = ["EliasFano"]


@dataclasses.dataclass
class EliasFano:
    n: int
    universe: int
    l: int
    low: np.ndarray        # packed low bits (uint64 words)
    high: BitVector        # unary-coded high parts

    @classmethod
    def encode(cls, ids: np.ndarray, universe: int) -> "EliasFano":
        ids = np.sort(np.asarray(ids, dtype=np.int64))
        n = int(ids.size)
        if n and (ids[0] < 0 or ids[-1] >= universe):
            raise ValueError("ids out of range")
        l = max(0, int(np.floor(np.log2(universe / n)))) if n else 0
        low = pack_lowbits(ids & ((1 << l) - 1), l) if n else np.zeros(0, np.uint64)
        high_positions = (ids >> l) + np.arange(n)
        nbits = int(n + (universe >> l) + 1)
        high = BitVector.from_positions(high_positions, nbits)
        return cls(n=n, universe=universe, l=l, low=low, high=high)

    def decode(self) -> np.ndarray:
        if self.n == 0:
            return np.zeros(0, dtype=np.int64)
        ones = self.high.one_positions()
        highs = ones - np.arange(self.n)
        lows = unpack_lowbits(self.low, self.l, self.n)
        return (highs << self.l) | lows

    def access(self, i: int) -> int:
        """Random access to the i-th smallest id (select on the high bits)."""
        pos = self.high.select1(i)
        high = pos - i
        low = int(unpack_lowbits(self.low, self.l, self.n, i, 1)[0]) if self.l else 0
        return (high << self.l) | low

    @property
    def size_bits(self) -> int:
        """Paper-comparable size: both bit streams, no rank/select overhead."""
        return self.n * self.l + self.high.nbits

    @property
    def size_bits_with_overheads(self) -> int:
        return self.size_bits + self.high.index_bits
