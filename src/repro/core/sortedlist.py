"""Blocked sorted list with O(sqrt n)-ish rank-insert.

Used by the REC decoder, which must maintain the sorted multiset of decoded
edges and report each insertion rank (hundreds of thousands of inserts —
a flat ``list.insert`` would be quadratic).  Blocks are plain Python lists
(C memmove on insert); a Fenwick over block sizes gives the global rank.
"""

from __future__ import annotations

import bisect
from typing import List

from .fenwick import Fenwick

__all__ = ["SortedList"]

_BLOCK = 1024


class SortedList:
    def __init__(self) -> None:
        self._blocks: List[List[int]] = [[]]
        self._maxs: List[int] = []           # max key per block (parallel)
        self._sizes = Fenwick([0])
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def insert(self, key) -> int:
        """Insert ``key``; returns its rank (bisect_left position)."""
        if self._len == 0:
            self._blocks[0].append(key)
            self._maxs = [key]
            self._sizes.add(0, 1)
            self._len = 1
            return 0
        bi = bisect.bisect_left(self._maxs, key)
        if bi == len(self._blocks):
            bi -= 1
        blk = self._blocks[bi]
        pos = bisect.bisect_left(blk, key)
        rank = self._sizes.cum(bi) + pos
        blk.insert(pos, key)
        self._sizes.add(bi, 1)
        if key > self._maxs[bi]:
            self._maxs[bi] = key
        self._len += 1
        if len(blk) >= 2 * _BLOCK:
            self._split(bi)
        return rank

    def _split(self, bi: int) -> None:
        blk = self._blocks[bi]
        mid = len(blk) // 2
        left, right = blk[:mid], blk[mid:]
        self._blocks[bi] = left
        self._blocks.insert(bi + 1, right)
        self._maxs[bi] = left[-1]
        self._maxs.insert(bi + 1, right[-1])
        # rebuild the size Fenwick (rare: amortized O(sqrt n) splits)
        self._sizes = Fenwick([len(b) for b in self._blocks])

    def to_list(self) -> List:
        out: List = []
        for b in self._blocks:
            out.extend(b)
        return out
