"""Offline index container: a whole IVF index as one compressed blob.

The paper's *offline* setting (§4.3) — the index is stored or transmitted
as a binary artifact and decompressed on load.  Ids for all clusters share
a single exact-ANS stream (amortizing everything; `log n_k!` collected per
cluster), PQ codes go through the Pólya coder, centroids ride along as
f16.  This is what a checkpoint of the `retrieval/` side-car stores,
and the unit the paper sizes in Table 4's "index" column.

Format (little-endian):
    magic "RIVF" | u32 version | u32 json_manifest_len | manifest |
    payload sections (offsets in the manifest)
"""

from __future__ import annotations

import io
import json
from typing import Optional

import numpy as np

from .ans import BigANS
from .polya import polya_decode_clusters, polya_encode_clusters
from .roc import roc_pop_set, roc_push_set

__all__ = ["pack_ivf", "unpack_ivf"]

_MAGIC = b"RIVF"
_VERSION = 1


def pack_ivf(index) -> bytes:
    """Serialize a built repro.ann.ivf.IVFIndex into one blob."""
    sizes = [int(s) for s in index.sizes]
    # ids: one joint exact-ANS stream, clusters pushed in order
    ans = BigANS()
    for k in range(index.nlist):
        ids = index._lists[k]
        if len(ids):
            roc_push_set(ans, ids, index.n)
    id_blob = ans.tobytes()

    sections = {}
    payload = io.BytesIO()

    def add(name: str, raw: bytes):
        sections[name] = [payload.tell(), len(raw)]
        payload.write(raw)

    add("ids", id_blob)
    cents = index.centroids.astype(np.float16)
    add("centroids", cents.tobytes())
    code_meta = None
    if getattr(index, "_code_blob", None) is not None:
        blob = index._code_blob
        add("code_heads", blob["heads"].astype(np.uint64).tobytes())
        words = blob["words"]
        lens = np.array([len(w) for w in words], np.int64)
        add("code_word_lens", lens.tobytes())
        add("code_words", np.concatenate(
            [w for w in words] or [np.zeros(0, np.uint32)]).tobytes())
        code_meta = {"m": blob["m"]}
    elif index.codes is not None:
        add("codes_raw", index.codes.tobytes())
        code_meta = {"m": int(index.codes.shape[1]), "raw": True}
    manifest = {
        "n": int(index.n), "d": int(index.d), "nlist": int(index.nlist),
        "sizes": sizes, "code": code_meta,
        "pq_m": int(index.pq.m) if index.pq else 0,
        "sections": sections,
    }
    mraw = json.dumps(manifest).encode()
    out = io.BytesIO()
    out.write(_MAGIC)
    out.write(np.uint32(_VERSION).tobytes())
    out.write(np.uint32(len(mraw)).tobytes())
    out.write(mraw)
    out.write(payload.getvalue())
    return out.getvalue()


def unpack_ivf(raw: bytes):
    """Returns (manifest, lists, centroids, codes|None)."""
    assert raw[:4] == _MAGIC, "not an RIVF container"
    ver = int(np.frombuffer(raw[4:8], np.uint32)[0])
    assert ver == _VERSION
    mlen = int(np.frombuffer(raw[8:12], np.uint32)[0])
    manifest = json.loads(raw[12:12 + mlen].decode())
    base = 12 + mlen

    def sec(name):
        off, ln = manifest["sections"][name]
        return raw[base + off: base + off + ln]

    n, nlist = manifest["n"], manifest["nlist"]
    sizes = manifest["sizes"]
    ans = BigANS.frombytes(sec("ids"))
    lists = [None] * nlist
    for k in range(nlist - 1, -1, -1):   # stack order: last pushed, first out
        lists[k] = (roc_pop_set(ans, sizes[k], n) if sizes[k]
                    else np.zeros(0, np.int64))
    cents = np.frombuffer(sec("centroids"), np.float16).reshape(
        nlist, manifest["d"]).astype(np.float32)
    codes = None
    cm = manifest["code"]
    if cm and cm.get("raw"):
        codes = np.frombuffer(sec("codes_raw"), np.uint8).reshape(-1, cm["m"])
    elif cm:
        heads = np.frombuffer(sec("code_heads"), np.uint64)
        lens = np.frombuffer(sec("code_word_lens"), np.int64)
        flat = np.frombuffer(sec("code_words"), np.uint32)
        words, off = [], 0
        for ln in lens:
            words.append(flat[off:off + ln])
            off += ln
        per = polya_decode_clusters(heads, words, sizes, cm["m"])
        codes = np.concatenate([c for c in per], axis=0)
    return manifest, lists, cents, codes
