"""Offline index containers: manifest-of-sections binary artifacts.

The paper's *offline* setting (§4.3) — the index is stored or transmitted
as a binary artifact and decompressed on load.  Two layers live here:

* :class:`SectionWriter` / :class:`SectionReader` — the generic
  manifest-of-sections framing every container version shares::

      magic | u32 version | u32 json_manifest_len | manifest |
      payload sections (offsets in the manifest["sections"] table)

  ``repro.api.container`` builds the RIDX-v2 any-index format on these.

* ``pack_ivf`` / ``unpack_ivf`` — the original v1 ``RIVF`` IVF-only blob
  (ids of all clusters share a single exact-ANS stream, PQ codes through
  the Pólya coder, centroids as f16), kept for backward compatibility and
  as the Table-4 "index" sizing unit.
"""

from __future__ import annotations

import io
import json
from typing import Dict

import numpy as np

from .ans import BigANS
from .polya import polya_decode_clusters
from .roc import roc_pop_set, roc_push_set

__all__ = [
    "pack_ivf", "unpack_ivf", "SectionWriter", "SectionReader",
    "pack_joint_ids", "unpack_joint_ids",
    "pack_polya_sections", "unpack_polya_sections",
]

_MAGIC = b"RIVF"
_VERSION = 1


class SectionWriter:
    """Accumulates named payload sections behind a JSON manifest.

    ``add(name, raw)`` appends bytes and records ``[offset, length]``;
    ``finish(magic, version, meta)`` frames the whole container.  The
    manifest is ``meta`` plus the ``sections`` table.
    """

    def __init__(self) -> None:
        self._payload = io.BytesIO()
        self._sections: Dict[str, list] = {}

    def add(self, name: str, raw: bytes) -> None:
        if name in self._sections:
            raise ValueError(f"duplicate section {name!r}")
        self._sections[name] = [self._payload.tell(), len(raw)]
        self._payload.write(raw)

    def finish(self, magic: bytes, version: int, meta: dict) -> bytes:
        manifest = dict(meta)
        manifest["sections"] = self._sections
        mraw = json.dumps(manifest).encode()
        out = io.BytesIO()
        out.write(magic)
        out.write(np.uint32(version).tobytes())
        out.write(np.uint32(len(mraw)).tobytes())
        out.write(mraw)
        out.write(self._payload.getvalue())
        return out.getvalue()


class SectionReader:
    """Parses a manifest-of-sections container produced by SectionWriter."""

    def __init__(self, raw: bytes, magic: bytes) -> None:
        if raw[: len(magic)] != magic:
            raise ValueError(f"not a {magic.decode(errors='replace')} container")
        p = len(magic)
        self.version = int(np.frombuffer(raw[p: p + 4], np.uint32)[0])
        mlen = int(np.frombuffer(raw[p + 4: p + 8], np.uint32)[0])
        self.manifest = json.loads(raw[p + 8: p + 8 + mlen].decode())
        self._base = p + 8 + mlen
        self._raw = raw

    def __contains__(self, name: str) -> bool:
        return name in self.manifest["sections"]

    def section(self, name: str) -> bytes:
        off, ln = self.manifest["sections"][name]
        return self._raw[self._base + off: self._base + off + ln]


def pack_joint_ids(lists, n: int) -> bytes:
    """Ids of all clusters as one joint exact-ANS stream (clusters in order)."""
    ans = BigANS()
    for ids in lists:
        if len(ids):
            roc_push_set(ans, ids, n)
    return ans.tobytes()


def unpack_joint_ids(raw: bytes, sizes, n: int):
    """Inverse of :func:`pack_joint_ids`: per-cluster sorted id arrays."""
    ans = BigANS.frombytes(raw)
    lists = [None] * len(sizes)
    for k in range(len(sizes) - 1, -1, -1):  # stack order: last pushed, first out
        lists[k] = (roc_pop_set(ans, int(sizes[k]), n) if sizes[k]
                    else np.zeros(0, np.int64))
    return lists


def pack_polya_sections(w: SectionWriter, blob, prefix: str = "code") -> dict:
    """Write a PolyaCodec blob's arrays as sections; returns its meta dict."""
    w.add(f"{prefix}_heads", blob["heads"].astype(np.uint64).tobytes())
    words = blob["words"]
    lens = np.array([len(x) for x in words], np.int64)
    w.add(f"{prefix}_word_lens", lens.tobytes())
    w.add(f"{prefix}_words", np.concatenate(
        [x for x in words] or [np.zeros(0, np.uint32)]).tobytes())
    return {"m": blob["m"], "bits": int(blob["bits"])}


def unpack_polya_sections(r: SectionReader, sizes, meta: dict,
                          prefix: str = "code"):
    """Inverse of :func:`pack_polya_sections`: the reconstructed blob dict."""
    heads = np.frombuffer(r.section(f"{prefix}_heads"), np.uint64)
    lens = np.frombuffer(r.section(f"{prefix}_word_lens"), np.int64)
    flat = np.frombuffer(r.section(f"{prefix}_words"), np.uint32)
    words, off = [], 0
    for ln in lens:
        words.append(flat[off:off + ln].copy())
        off += ln
    return {"heads": heads.copy(), "words": words, "bits": meta["bits"],
            "sizes": [int(s) for s in sizes], "m": meta["m"]}


def pack_ivf(index) -> bytes:
    """Serialize a built repro.ann.ivf.IVFIndex into one v1 RIVF blob."""
    sizes = [int(s) for s in index.sizes]
    w = SectionWriter()
    w.add("ids", pack_joint_ids(index._lists, index.n))
    w.add("centroids", index.centroids.astype(np.float16).tobytes())
    code_meta = None
    if getattr(index, "_code_blob", None) is not None:
        # v1 manifests carry only {"m"} for the polya payload
        code_meta = {"m": pack_polya_sections(w, index._code_blob)["m"]}
    elif index.codes is not None:
        w.add("codes_raw", index.codes.tobytes())
        code_meta = {"m": int(index.codes.shape[1]), "raw": True}
    return w.finish(_MAGIC, _VERSION, {
        "n": int(index.n), "d": int(index.d), "nlist": int(index.nlist),
        "sizes": sizes, "code": code_meta,
        "pq_m": int(index.pq.m) if index.pq else 0,
    })


def unpack_ivf(raw: bytes):
    """Returns (manifest, lists, centroids, codes|None)."""
    r = SectionReader(raw, _MAGIC)
    assert r.version == _VERSION
    manifest = r.manifest
    n, nlist = manifest["n"], manifest["nlist"]
    sizes = manifest["sizes"]
    lists = unpack_joint_ids(r.section("ids"), sizes, n)
    cents = np.frombuffer(r.section("centroids"), np.float16).reshape(
        nlist, manifest["d"]).astype(np.float32)
    codes = None
    cm = manifest["code"]
    if cm and cm.get("raw"):
        codes = np.frombuffer(r.section("codes_raw"), np.uint8).reshape(-1, cm["m"])
    elif cm:
        heads = np.frombuffer(r.section("code_heads"), np.uint64)
        lens = np.frombuffer(r.section("code_word_lens"), np.int64)
        flat = np.frombuffer(r.section("code_words"), np.uint32)
        words, off = [], 0
        for ln in lens:
            words.append(flat[off:off + ln])
            off += ln
        per = polya_decode_clusters(heads, words, sizes, cm["m"])
        codes = np.concatenate([c for c in per], axis=0)
    return manifest, lists, cents, codes
