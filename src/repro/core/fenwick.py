"""Fenwick (binary indexed) tree for order statistics and adaptive CDFs.

Used by:
  * ROC for O(log n) select-by-rank / remove on large clusters
    (``repro.core.roc``),
  * the REC Pólya-urn vertex model (``repro.core.rec``), where it stores
    per-vertex occurrence weights and answers ``cum(v)``, ``find(cf)``
    queries — this is the structure the paper identifies as the dominant
    runtime cost of ANS-based id coding (Section 5.2).

Pure-Python ints; the tree size is a power of two for branch-free ``find``.
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np

__all__ = ["Fenwick"]


class Fenwick:
    """Prefix-sum tree over ``n`` slots of non-negative integer weights."""

    __slots__ = ("n", "size", "tree", "total")

    def __init__(self, weights: Iterable[int] | int):
        if isinstance(weights, int):
            w: List[int] = [0] * weights
        else:
            w = [int(x) for x in weights]
        self.n = len(w)
        size = 1
        while size < self.n:
            size <<= 1
        self.size = size
        # O(size) build: tree[i] covers (i - lowbit(i), i]; propagation must
        # run over ALL tree nodes (including those above n) so internal
        # nodes beyond the data range carry complete partial sums.
        tree = [0] * (size + 1)
        tree[1 : self.n + 1] = w
        for i in range(1, size):
            j = i + (i & (-i))
            if j <= size:
                tree[j] += tree[i]
        self.tree = tree
        self.total = sum(w)

    @classmethod
    def ones(cls, n: int) -> "Fenwick":
        return cls([1] * n)

    def add(self, i: int, delta: int) -> None:
        """Add ``delta`` to slot ``i``."""
        self.total += delta
        i += 1
        while i <= self.size:
            self.tree[i] += delta
            i += i & (-i)

    def cum(self, i: int) -> int:
        """Sum of weights of slots ``< i`` (exclusive prefix sum)."""
        s = 0
        while i > 0:
            s += self.tree[i]
            i -= i & (-i)
        return s

    def get(self, i: int) -> int:
        return self.cum(i + 1) - self.cum(i)

    def find(self, cf: int) -> int:
        """Largest ``i`` such that ``cum(i) <= cf``; i.e. the slot whose
        cumulative interval ``[cum(i), cum(i)+w_i)`` contains ``cf``."""
        pos = 0
        half = self.size
        rem = cf
        tree = self.tree
        while half > 0:
            nxt = pos + half
            if nxt <= self.size and tree[nxt] <= rem:
                rem -= tree[nxt]
                pos = nxt
            half >>= 1
        return pos  # 0-based slot

    def to_array(self) -> np.ndarray:
        return np.array([self.get(i) for i in range(self.n)], dtype=np.int64)
