"""Adaptive per-cluster entropy coding of PQ codes — paper Eq. (6)-(7).

Vector quantizers are assumed to produce max-entropy codes, but *conditioned
on the IVF cluster* the per-subquantizer code distribution is skewed (the
cluster already pins down part of the vector).  The paper codes each PQ
column within each cluster with the sequential Pólya-urn estimator::

    Pr(x_i = x | x_0..x_{i-1}) = (1 + #occurrences of x so far) / (256 + i)

Implementation notes (DESIGN.md §3.5): the urn total ``256+i`` is not a
power of two, so for the streaming coder we quantize the urn to ``2^16``
before every op — both encoder and decoder derive the quantization from
identical counts, so it is exactly reproducible; redundancy is O(256/2^16)
bits/op.  All clusters are coded in *lockstep lanes* (vectorized numpy ops
over a (n_clusters, 256) count matrix) but each cluster owns its private
word stream, preserving the paper's online setting (random access at
cluster granularity; one stream per cluster spanning all m columns, so the
64-bit head is amortized over ``n_k * m`` symbols).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["PolyaCodec", "polya_encode_clusters", "polya_decode_clusters"]

_R = 16
_TOTAL = 1 << _R
_ALPHA = 256  # PQ byte alphabet
_WORDBITS = 32
_LOW = np.uint64(1) << np.uint64(32)
_MASK32 = np.uint64(0xFFFFFFFF)


def _quantized_model(counts: np.ndarray, t: int) -> Tuple[np.ndarray, np.ndarray]:
    """(freqs, cums_exclusive), both (C, 256), summing to exactly 2^16."""
    raw_total = _ALPHA + t
    freqs = ((counts + 1) * _TOTAL) // raw_total          # each >= 1 for t <= 65279
    deficit = _TOTAL - freqs.sum(axis=1)
    freqs[:, -1] += deficit                               # exact fixup, last symbol
    cums = np.cumsum(freqs, axis=1) - freqs               # exclusive
    return freqs, cums


@dataclasses.dataclass
class _LaneStreams:
    """Per-lane rANS with private word stacks (cluster-granular access)."""

    lanes: int

    def __post_init__(self) -> None:
        self.heads = np.full(self.lanes, int(_LOW), dtype=np.uint64)
        self.words: List[List[int]] = [[] for _ in range(self.lanes)]

    def push(self, starts, freqs, mask) -> None:
        heads = self.heads
        starts = starts.astype(np.uint64)
        freqs = freqs.astype(np.uint64)
        need = (heads >= (freqs << np.uint64(64 - _R))) & mask
        for lane in np.flatnonzero(need):
            self.words[lane].append(int(heads[lane] & _MASK32))
        heads = np.where(need, heads >> np.uint64(_WORDBITS), heads)
        safe_f = np.where(mask, freqs, np.uint64(1))
        upd = ((heads // safe_f) << np.uint64(_R)) + starts + (heads % safe_f)
        self.heads = np.where(mask, upd, heads)


def polya_encode_clusters(
    clusters: Sequence[np.ndarray],
) -> Tuple[np.ndarray, List[np.ndarray], int]:
    """Encode per-cluster PQ code matrices [(n_k, m) uint8, ...].

    Returns (heads (C,) uint64, per-cluster word arrays, total_bits).
    Encoding runs columns j = m-1..0 and rows t = n_max-1..0 in reverse so
    decoding streams forward; word lists are reversed at the end.
    """
    C = len(clusters)
    sizes = np.array([c.shape[0] for c in clusters], dtype=np.int64)
    m = clusters[0].shape[1]
    n_max = int(sizes.max())
    # (C, n_max, m) padded symbol cube
    cube = np.zeros((C, n_max, m), dtype=np.int64)
    for k, c in enumerate(clusters):
        cube[k, : c.shape[0]] = c
    st = _LaneStreams(C)
    lane_idx = np.arange(C)
    for j in range(m - 1, -1, -1):
        counts = np.zeros((C, _ALPHA), dtype=np.int64)
        np.add.at(counts, (np.repeat(lane_idx, sizes),
                           np.concatenate([c[:, j] for c in clusters])), 1)
        for t in range(n_max - 1, -1, -1):
            active = t < sizes
            x = cube[:, t, j]
            counts[lane_idx[active], x[active]] -= 1
            freqs, cums = _quantized_model(counts, t)
            st.push(cums[lane_idx, x], freqs[lane_idx, x], active)
    words = [np.asarray(w[::-1], dtype=np.uint32) for w in st.words]
    total_bits = 64 * C + 32 * sum(len(w) for w in words)
    return st.heads, words, total_bits


def polya_decode_clusters(
    heads: np.ndarray,
    words: Sequence[np.ndarray],
    sizes: Sequence[int],
    m: int,
) -> List[np.ndarray]:
    """Inverse of :func:`polya_encode_clusters` (vectorized lockstep)."""
    C = len(sizes)
    sizes = np.asarray(sizes, dtype=np.int64)
    n_max = int(sizes.max())
    heads = heads.astype(np.uint64).copy()
    wmax = max((len(w) for w in words), default=0)
    wmat = np.zeros((C, wmax), dtype=np.uint64)
    for k, w in enumerate(words):
        wmat[k, : len(w)] = w
    ptr = np.zeros(C, dtype=np.int64)
    lane_idx = np.arange(C)
    cube = np.zeros((C, n_max, m), dtype=np.int64)
    for j in range(m):
        counts = np.zeros((C, _ALPHA), dtype=np.int64)
        for t in range(n_max):
            active = t < sizes
            freqs, cums = _quantized_model(counts, t)
            cum_incl = cums + freqs
            cf = (heads & np.uint64(_TOTAL - 1)).astype(np.int64)
            sym = (cum_incl <= cf[:, None]).sum(axis=1)
            f = freqs[lane_idx, sym].astype(np.uint64)
            c = cums[lane_idx, sym].astype(np.uint64)
            upd = f * (heads >> np.uint64(_R)) + cf.astype(np.uint64) - c
            heads = np.where(active, upd, heads)
            need = (heads < _LOW) & active
            if need.any():
                refill = wmat[lane_idx, np.minimum(ptr, wmax - 1)]
                heads = np.where(
                    need, (heads << np.uint64(_WORDBITS)) | refill, heads
                )
                ptr = ptr + need
            cube[:, t, j] = np.where(active, sym, 0)
            counts[lane_idx[active], sym[active]] += 1
    return [cube[k, : int(sizes[k])].astype(np.uint8) for k in range(C)]


@dataclasses.dataclass
class PolyaCodec:
    """Facade used by the IVF index and the Fig-3 benchmark."""

    def encode(self, clusters: Sequence[np.ndarray]):
        heads, words, bits = polya_encode_clusters(clusters)
        return {"heads": heads, "words": words, "bits": bits,
                "sizes": [c.shape[0] for c in clusters],
                "m": clusters[0].shape[1]}

    def decode(self, blob) -> List[np.ndarray]:
        return polya_decode_clusters(
            blob["heads"], blob["words"], blob["sizes"], blob["m"]
        )

    def bits_per_element(self, blob) -> float:
        nsym = sum(blob["sizes"]) * blob["m"]
        return blob["bits"] / max(1, nsym)
