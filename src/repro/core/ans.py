"""Exact stack ANS coders.

Two coders live here:

``BigANS``
    An *exact* asymmetric numeral system over an unbounded Python integer
    state.  ``push``/``pop`` are exact bijections, so the coder attains the
    information-theoretic rate with **zero** redundancy (no quantization, no
    renormalization slop).  This is the reference coder used by ROC
    (``repro.core.roc``) and by all oracles in the test-suite.  The paper's
    Eq. (1)-(3) are implemented verbatim; for uniform models we use the
    mixed-radix special case ``s' = s*n + x`` which is Eq. (1) with
    ``p_x = 1, r = n``.

``StreamANS``
    A fixed-width streaming rANS (64-bit head, 32-bit word renormalization)
    with power-of-two totals ``2^r`` (``r`` may vary per op).  With the
    global interval ``I = [2^32, 2^64)`` and symbol intervals
    ``I_s = [freq*2^(32-r), freq*2^(64-r))`` the coder is an exact bijection
    (Duda's b-uniqueness: ``2^r`` divides ``2^32`` for r <= 32), emitting /
    consuming at most one 32-bit word per op.  Adaptive models with
    non-power-of-two raw totals (REC urn, Polya PQ coder) quantize their
    counts to ``2^r`` before each op — both sides of the codec see identical
    counts, so the quantization is reproducible; the redundancy is
    ``O(alphabet/2^r)`` bits/op.  Exact arbitrary-total coding is available
    via ``BigANS``.

The vectorized (lane-parallel) coder lives in ``repro.core.vrans``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

__all__ = ["BigANS", "StreamANS"]


class BigANS:
    """Exact ANS over an unbounded integer state.

    The state starts at 0; ``bits`` is the exact information content of
    everything pushed so far.  pops executed on a small state are still
    exact bijections (they simply return low-entropy values), which is what
    makes bits-back coding with ``s0 = 0`` work without an initial-bits
    overhead (see repro.core.roc).
    """

    __slots__ = ("state",)

    def __init__(self, state: int = 0):
        self.state = int(state)

    # -- uniform model: exact mixed-radix coding --------------------------
    def push_uniform(self, x: int, n: int) -> None:
        """Append symbol ``x`` under the uniform model over ``[n)``."""
        if not 0 <= x < n:
            raise ValueError(f"symbol {x} out of range [0, {n})")
        self.state = self.state * n + x

    def pop_uniform(self, n: int) -> int:
        """Pop a symbol under the uniform model over ``[n)`` (inverse of push)."""
        s = self.state
        x = s % n
        self.state = s // n
        return int(x)

    # -- general quantized pmf (paper Eq. (1)-(3)) ------------------------
    def push_pmf(self, cum: int, freq: int, total: int) -> None:
        """Append a symbol with quantized pmf ``freq/total`` and CDF ``cum``."""
        if freq <= 0:
            raise ValueError("zero-frequency symbol cannot be encoded")
        s = self.state
        self.state = (s // freq) * total + cum + (s % freq)

    def pop_cf(self, total: int) -> int:
        """Peek the cumulative-frequency slot of the next symbol (Eq. (2))."""
        return int(self.state % total)

    def pop_advance(self, cum: int, freq: int, total: int) -> None:
        """Advance the state after the symbol for ``pop_cf`` was identified."""
        s = self.state
        cf = s % total
        self.state = freq * (s // total) + cf - cum

    # -- serialization -----------------------------------------------------
    @property
    def bits(self) -> int:
        """Exact size, in bits, of the current state."""
        return self.state.bit_length()

    def tobytes(self) -> bytes:
        nbytes = (self.state.bit_length() + 7) // 8
        return self.state.to_bytes(nbytes, "little")

    @classmethod
    def frombytes(cls, raw: bytes) -> "BigANS":
        return cls(int.from_bytes(raw, "little"))

    def __repr__(self) -> str:  # pragma: no cover
        return f"BigANS(bits={self.bits})"


@dataclasses.dataclass
class StreamANS:
    """Fixed-width streaming rANS, power-of-two totals (64/32 single-renorm).

    Invariant: ``head in [2^32, 2^64)``.  Per op (precision ``r <= 32``):
    the encoder renormalizes into the symbol interval
    ``[freq*2^(32-r), freq*2^(64-r))`` by emitting at most one 32-bit word
    (``freq*2^(64-r) >= 2^32`` guarantees one suffices), then applies
    Eq. (1); the decoder applies Eq. (2)-(3) and consumes at most one word
    when the head drops below ``2^32``.  Exact bijection by b-uniqueness
    (``2^r | 2^32``).
    """

    head: int = 1 << 32          # seed; must be in [2^32, 2^64)
    tail: List[int] = dataclasses.field(default_factory=list)  # 32-bit words

    _WORD = 32
    _MASK = (1 << 32) - 1
    _LOW = 1 << 32

    def push(self, cum: int, freq: int, r: int) -> None:
        """Push a symbol with quantized pmf ``freq / 2^r`` and CDF ``cum``."""
        if freq <= 0:
            raise ValueError("zero-frequency symbol cannot be encoded")
        if r < 0 or r > 32:
            raise ValueError("precision must be in [0, 32]")
        if r == 0:               # zero-information symbol
            return
        if self.head >= freq << (64 - r):
            self.tail.append(self.head & self._MASK)
            self.head >>= self._WORD
        self.head = ((self.head // freq) << r) + cum + (self.head % freq)

    def pop_cf(self, r: int) -> int:
        return int(self.head & ((1 << r) - 1))

    def pop_advance(self, cum: int, freq: int, r: int) -> None:
        if r == 0:               # zero-information symbol
            return
        cf = self.head & ((1 << r) - 1)
        self.head = freq * (self.head >> r) + cf - cum
        if self.head < self._LOW:
            if not self.tail:
                raise ValueError("ANS stream underflow (corrupt or over-read)")
            self.head = (self.head << self._WORD) | self.tail.pop()

    def push_uniform_pow2(self, x: int, r: int) -> None:
        self.push(x, 1, r)

    def pop_uniform_pow2(self, r: int) -> int:
        x = self.pop_cf(r)
        self.pop_advance(x, 1, r)
        return x

    @property
    def bits(self) -> int:
        return len(self.tail) * self._WORD + self.head.bit_length()

    def tobytes(self) -> Tuple[bytes, bytes]:
        import numpy as np

        words = np.asarray(self.tail, dtype=np.uint32)
        nbytes = (self.head.bit_length() + 7) // 8
        return self.head.to_bytes(nbytes, "little"), words.tobytes()

    @classmethod
    def frombytes(cls, head_raw: bytes, tail_raw: bytes) -> "StreamANS":
        import numpy as np

        head = int.from_bytes(head_raw, "little")
        tail = np.frombuffer(tail_raw, dtype=np.uint32)
        return cls(head=head, tail=[int(w) for w in tail])
