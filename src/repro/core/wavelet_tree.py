"""Wavelet tree over the cluster-assignment string (paper §3.3 / §4.1).

The IVF id lists jointly form a partition of ``[N)``; instead of storing K
separate lists, index the string ``S in [K)^N`` where ``S[i]`` = cluster of
id ``i``.  The id at offset ``O`` of cluster ``k`` is then
``select_k(S, O)`` — full random access, which is exactly what the paper's
§4.1 search trick needs: the scanner accumulates ``(k, O)`` pairs and only
the final top-k results are resolved to ids.

Structure: one bitvector per level (pointerless, node boundaries kept as a
small per-level offset table).  ``WT`` backs levels with flat
``BitVector``s; ``WT1`` with RRR-compressed ``RRRVector``s (slower select,
better rate on skewed partitions — Table 1's WT vs WT1 trade-off).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .bitvec import BitVector
from .rrr import RRRVector

__all__ = ["WaveletTree"]


@dataclasses.dataclass
class WaveletTree:
    nsyms: int                       # K
    nlevels: int
    length: int                      # N
    levels: List[object]             # BitVector | RRRVector per level
    bounds: List[np.ndarray]         # per level: node start offsets (2^d + 1)
    compressed: bool

    @classmethod
    def build(cls, s: np.ndarray, nsyms: int, compressed: bool = False) -> "WaveletTree":
        s = np.asarray(s, dtype=np.int64)
        if s.size and (s.min() < 0 or s.max() >= nsyms):
            raise ValueError("symbols out of range")
        nlevels = max(1, int(np.ceil(np.log2(max(2, nsyms)))))
        levels: List[object] = []
        bounds: List[np.ndarray] = []
        order = s.copy()  # symbols arranged in current level order
        for d in range(nlevels):
            shift = nlevels - 1 - d
            bit = (order >> shift) & 1
            # node of each element at this level = prefix bits above `shift`
            node = order >> (shift + 1)
            nnodes = 1 << d
            counts = np.bincount(node, minlength=nnodes)
            starts = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
            bounds.append(starts)
            vec = (
                RRRVector.from_bits(bit.astype(np.uint8))
                if compressed
                else BitVector.from_bits(bit.astype(np.uint8))
            )
            levels.append(vec)
            # stable partition within each node for the next level
            key = node * 2 + bit
            order = order[np.argsort(key, kind="stable")]
        return cls(
            nsyms=nsyms,
            nlevels=nlevels,
            length=int(s.size),
            levels=levels,
            bounds=bounds,
            compressed=compressed,
        )

    # -- queries ------------------------------------------------------------
    def access(self, i: int) -> int:
        """S[i]: the cluster of id ``i`` (top-down rank walk)."""
        sym = 0
        pos = i
        for d in range(self.nlevels):
            vec = self.levels[d]
            lo = int(self.bounds[d][sym])
            bit = self._bit(vec, lo + pos)
            ones_before = vec.rank1(lo + pos) - vec.rank1(lo)
            pos = ones_before if bit else (pos - ones_before)
            sym = sym * 2 + bit
        return sym

    @staticmethod
    def _bit(vec, pos: int) -> int:
        return vec.rank1(pos + 1) - vec.rank1(pos)

    def select(self, k: int, occ: int) -> int:
        """Global index of the ``occ``-th (0-based) occurrence of symbol k.

        This is the paper's (cluster, offset) -> id resolution (§4.1).
        """
        if not 0 <= k < self.nsyms:
            raise IndexError("symbol out of range")
        pos = occ
        for d in range(self.nlevels - 1, -1, -1):
            shift = self.nlevels - 1 - d
            bit = (k >> shift) & 1
            node = k >> (shift + 1)
            vec = self.levels[d]
            lo = int(self.bounds[d][node])
            ones_lo = vec.rank1(lo)
            if bit:
                pos = vec.select1(ones_lo + pos) - lo
            else:
                zeros_lo = lo - ones_lo
                pos = vec.select0(zeros_lo + pos) - lo
        return pos

    def select_batch(self, ks: Sequence[int], occs: Sequence[int]) -> np.ndarray:
        return np.array([self.select(int(k), int(o)) for k, o in zip(ks, occs)])

    def cluster_size(self, k: int) -> int:
        # occurrences of k = ones (or zeros) of k's leaf-level node segment
        d = self.nlevels - 1
        node = k >> 1
        vec = self.levels[d]
        lo = int(self.bounds[d][node])
        hi = int(self.bounds[d][node + 1])
        ones = vec.rank1(hi) - vec.rank1(lo)
        return ones if (k & 1) else (hi - lo - ones)

    def decode_cluster(self, k: int) -> np.ndarray:
        """All ids of cluster k, ascending (select is order-preserving)."""
        n = self.cluster_size(k)
        return np.array([self.select(k, o) for o in range(n)], dtype=np.int64)

    # -- sizes ----------------------------------------------------------------
    @property
    def size_bits(self) -> int:
        """Payload bits (paper-comparable, excludes rank/select indexes)."""
        return int(sum(v.size_bits for v in self.levels))

    @property
    def index_bits(self) -> int:
        b = sum(v.index_bits for v in self.levels)
        b += sum(32 * len(x) for x in self.bounds)
        return int(b)

    def bits_per_id(self) -> float:
        return self.size_bits / max(1, self.length)
