"""Vectorized interleaved-lane rANS (the TPU adaptation of the paper's coder).

A single ANS stream is sequential: each push/pop depends on the previous
state.  TPUs (and the numpy model here) want wide data-parallel ops, so we
run ``L`` independent lanes in lockstep — one ``(L,)`` uint64 head vector —
and round-robin symbols over lanes.  Renormalization emits/consumes 32-bit
words into a single flat stack; each op emits *at most one* word per lane
(64/32 scheme with power-of-two totals, exact by b-uniqueness — see
``repro.core.ans.StreamANS``), and the decoder's consume mask provably
mirrors the encoder's emit mask, so the words of one op are contiguous and
lane-ordered: a dense layout that maps onto TPU vector loads with a
prefix-sum word distribution (see ``repro.kernels.rans_decode``).

Precision ``r`` (``total = 2^r``, ``r <= 32``) may vary per op; per-lane
``(start, freq)`` pairs are supported, as are lane masks for ragged data.

Encoding processes symbols in *reverse* op order so that decoding streams
forward; ``finalize`` reverses the word chunks accordingly.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["VRansEncoder", "VRansDecoder", "vrans_size_bits"]

_WORD = np.uint64(32)
_MASK32 = np.uint64(0xFFFFFFFF)
_LOW = np.uint64(1) << np.uint64(32)
_ONE = np.uint64(1)


@dataclasses.dataclass
class VRansEncoder:
    """Encoder over ``lanes`` parallel rANS streams.

    Symbols must be pushed in reverse of the intended decode order.
    """

    lanes: int

    def __post_init__(self) -> None:
        self.heads = np.full(self.lanes, int(_LOW), dtype=np.uint64)
        self._chunks: List[np.ndarray] = []  # appended word groups (encode order)

    def push(
        self,
        starts: np.ndarray,
        freqs: np.ndarray,
        r: int,
        mask: Optional[np.ndarray] = None,
    ) -> None:
        """Push one symbol per active lane: pmf ``freqs/2^r``, CDF ``starts``."""
        if r == 0:
            return
        if not 0 < r <= 32:
            raise ValueError("precision must be in (0, 32]")
        heads = self.heads
        starts = starts.astype(np.uint64)
        freqs = freqs.astype(np.uint64)
        live = (
            np.ones(self.lanes, dtype=bool)
            if mask is None
            else np.asarray(mask, dtype=bool)
        )
        need = (heads >= (freqs << np.uint64(64 - r))) & live
        if need.any():
            self._chunks.append((heads[need] & _MASK32).astype(np.uint32))
            heads = np.where(need, heads >> _WORD, heads)
        safe_f = np.where(live, freqs, _ONE)
        upd = ((heads // safe_f) << np.uint64(r)) + starts + (heads % safe_f)
        self.heads = np.where(live, upd, heads)

    def push_uniform(
        self, xs: np.ndarray, r: int, mask: Optional[np.ndarray] = None
    ) -> None:
        xs = np.asarray(xs).astype(np.uint64)
        self.push(xs, np.ones_like(xs), r, mask)

    def finalize(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns ``(heads (L,) uint64, words (W,) uint32)``.

        ``words`` is ordered so the decoder reads it *forward*: the encoder
        pushed ops in reverse decode order, so the chunk list is reversed.
        """
        if self._chunks:
            words = np.concatenate(self._chunks[::-1])
        else:
            words = np.zeros(0, dtype=np.uint32)
        return self.heads.copy(), words


@dataclasses.dataclass
class VRansDecoder:
    heads: np.ndarray  # (L,) uint64
    words: np.ndarray  # (W,) uint32, consumed front-to-back

    def __post_init__(self) -> None:
        self.heads = self.heads.astype(np.uint64).copy()
        self.words = np.asarray(self.words, dtype=np.uint32)
        self.ptr = 0

    def peek_cf(self, r: int) -> np.ndarray:
        return (self.heads & np.uint64((1 << r) - 1)).astype(np.int64)

    def advance(
        self,
        starts: np.ndarray,
        freqs: np.ndarray,
        r: int,
        mask: Optional[np.ndarray] = None,
    ) -> None:
        if r == 0:
            return
        heads = self.heads
        starts = starts.astype(np.uint64)
        freqs = freqs.astype(np.uint64)
        live = (
            np.ones(heads.shape[0], dtype=bool)
            if mask is None
            else np.asarray(mask, dtype=bool)
        )
        cf = heads & np.uint64((1 << r) - 1)
        upd = freqs * (heads >> np.uint64(r)) + cf - starts
        heads = np.where(live, upd, heads)
        need = (heads < _LOW) & live
        cnt = int(need.sum())
        if cnt:
            if self.ptr + cnt > self.words.shape[0]:
                raise ValueError("vrANS stream underflow (corrupt or over-read)")
            grp = self.words[self.ptr : self.ptr + cnt].astype(np.uint64)
            self.ptr += cnt
            refill = np.zeros_like(heads)
            refill[need] = grp
            heads = np.where(need, (heads << _WORD) | refill, heads)
        self.heads = heads

    def pop_uniform(
        self, r: int, mask: Optional[np.ndarray] = None
    ) -> np.ndarray:
        xs = self.peek_cf(r)
        ones = np.ones(self.heads.shape[0], dtype=np.uint64)
        self.advance(xs.astype(np.uint64), ones, r, mask)
        return xs


def vrans_size_bits(heads: np.ndarray, words: np.ndarray) -> int:
    """Serialized size: lane heads at 64b each + 32b per tail word."""
    return 64 * int(heads.shape[0]) + 32 * int(words.shape[0])


# ---------------------------------------------------------------------------
# 32/16 variant: uint32 heads, 16-bit words — the TPU-kernel coder.
# TPUs have no native 64-bit integer path; with head in [2^16, 2^32) and
# r <= 16, every operation (including freq * (head >> r)) fits uint32
# exactly, so the Pallas decoder (repro.kernels.rans_decode) runs on pure
# 32-bit vector arithmetic.  Same single-renorm mirror proof as 64/32.
# ---------------------------------------------------------------------------

_LOW16 = np.uint32(1) << np.uint32(16)
_MASK16 = np.uint32(0xFFFF)


@dataclasses.dataclass
class VRans16Encoder:
    """Lane-parallel 32/16 rANS encoder (push in reverse decode order)."""

    lanes: int

    def __post_init__(self) -> None:
        self.heads = np.full(self.lanes, int(_LOW16), dtype=np.uint32)
        self._chunks: List[np.ndarray] = []

    def push(self, starts, freqs, r: int, mask=None) -> None:
        if r == 0:
            return
        if not 0 < r <= 16:
            raise ValueError("precision must be in (0, 16]")
        heads = self.heads
        starts = np.asarray(starts).astype(np.uint32)
        freqs = np.asarray(freqs).astype(np.uint32)
        live = (
            np.ones(self.lanes, dtype=bool)
            if mask is None else np.asarray(mask, dtype=bool)
        )
        need = (heads >= (freqs << np.uint32(32 - r))) & live
        if need.any():
            self._chunks.append((heads[need] & _MASK16).astype(np.uint16))
            heads = np.where(need, heads >> np.uint32(16), heads)
        safe_f = np.where(live, freqs, np.uint32(1))
        upd = ((heads // safe_f) << np.uint32(r)) + starts + (heads % safe_f)
        self.heads = np.where(live, upd, heads)

    def push_uniform(self, xs, r: int, mask=None) -> None:
        xs = np.asarray(xs).astype(np.uint32)
        self.push(xs, np.ones_like(xs), r, mask)

    def finalize(self):
        words = (
            np.concatenate(self._chunks[::-1])
            if self._chunks else np.zeros(0, dtype=np.uint16)
        )
        return self.heads.copy(), words


@dataclasses.dataclass
class VRans16Decoder:
    """Numpy mirror of the Pallas decoder (for tests / CPU fallback)."""

    heads: np.ndarray
    words: np.ndarray

    def __post_init__(self) -> None:
        self.heads = self.heads.astype(np.uint32).copy()
        self.words = np.asarray(self.words, dtype=np.uint16)
        self.ptr = 0

    def peek_cf(self, r: int) -> np.ndarray:
        return (self.heads & np.uint32((1 << r) - 1)).astype(np.int64)

    def advance(self, starts, freqs, r: int, mask=None) -> None:
        if r == 0:
            return
        heads = self.heads
        starts = np.asarray(starts).astype(np.uint32)
        freqs = np.asarray(freqs).astype(np.uint32)
        live = (
            np.ones(heads.shape[0], dtype=bool)
            if mask is None else np.asarray(mask, dtype=bool)
        )
        cf = heads & np.uint32((1 << r) - 1)
        upd = freqs * (heads >> np.uint32(r)) + cf - starts
        heads = np.where(live, upd, heads)
        need = (heads < _LOW16) & live
        cnt = int(need.sum())
        if cnt:
            if self.ptr + cnt > self.words.shape[0]:
                raise ValueError("vrANS16 stream underflow")
            grp = self.words[self.ptr:self.ptr + cnt].astype(np.uint32)
            self.ptr += cnt
            refill = np.zeros_like(heads)
            refill[need] = grp
            heads = np.where(need, (heads << np.uint32(16)) | refill, heads)
        self.heads = heads

    def pop_uniform(self, r: int, mask=None) -> np.ndarray:
        xs = self.peek_cf(r)
        ones = np.ones(self.heads.shape[0], dtype=np.uint32)
        self.advance(xs.astype(np.uint32), ones, r, mask)
        return xs
