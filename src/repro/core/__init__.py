# The paper's primary contribution: lossless compression of ANN index
# auxiliary data (vector ids, graph links, PQ codes) via order-invariance.
#
#   ans          — exact BigANS + streaming rANS (Eq. 1-3)
#   vrans        — vectorized interleaved-lane rANS (TPU adaptation)
#   roc          — Random Order Coding for id sets (bits-back, §3.2)
#   gap_ans      — sorted-gap + lane-rANS set codec (beyond-paper fast path)
#   elias_fano   — EF baseline (§A.1)
#   wavelet_tree — WT / WT1 full-random-access structure (§3.3, §4.1)
#   rec          — Random Edge Coding for whole graphs (§4.3)
#   polya        — adaptive PQ-code coding conditioned on clusters (Eq. 6-7)
#   webgraph_lite— Zuckerli baseline stand-in (§A.2)
#   codecs       — the pluggable registry the index layer consumes

from .ans import BigANS, StreamANS
from .codecs import CODEC_NAMES, get_codec
from .elias_fano import EliasFano
from .fenwick import Fenwick
from .gap_ans import decode_gaps, encode_gaps
from .polya import PolyaCodec, polya_decode_clusters, polya_encode_clusters
from .rec import rec_decode, rec_encode
from .roc import (
    roc_decode_clusters,
    roc_encode_clusters,
    roc_pop_set,
    roc_push_set,
    set_information_bits,
)
from .vrans import VRansDecoder, VRansEncoder, vrans_size_bits
from .wavelet_tree import WaveletTree

__all__ = [
    "BigANS", "StreamANS", "CODEC_NAMES", "get_codec", "EliasFano",
    "Fenwick", "encode_gaps", "decode_gaps", "PolyaCodec",
    "polya_encode_clusters", "polya_decode_clusters", "rec_encode",
    "rec_decode", "roc_push_set", "roc_pop_set", "roc_encode_clusters",
    "roc_decode_clusters", "set_information_bits", "VRansEncoder",
    "VRansDecoder", "vrans_size_bits", "WaveletTree",
]
