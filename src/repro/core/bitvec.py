"""Bit-packed vectors with rank/select — substrate for EF, WT and RRR.

Storage is little-endian packed uint8 (``np.packbits(bitorder="little")``);
rank uses byte-popcount cumulative sums sampled per superblock
(``np.bitwise_count`` is a hardware popcount on numpy >= 2.0); select is a
binary search over the sampled ranks.  The sampled structures are reported
as ``index_bits`` and excluded from the paper-comparable payload size,
matching how the paper reports Elias-Fano ("without overheads").
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["BitVector", "pack_lowbits", "unpack_lowbits"]

_SUPER = 64  # bytes per superblock (512 bits)


@dataclasses.dataclass
class BitVector:
    data: np.ndarray      # packed uint8, little-endian bit order
    nbits: int

    def __post_init__(self) -> None:
        counts = np.bitwise_count(self.data).astype(np.int64)
        # cumulative popcount before each superblock boundary
        self._byte_cum = np.concatenate([[0], np.cumsum(counts)])
        self.nones = int(self._byte_cum[-1])

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_bits(cls, bits: np.ndarray) -> "BitVector":
        bits = np.asarray(bits, dtype=np.uint8)
        return cls(np.packbits(bits, bitorder="little"), int(bits.size))

    @classmethod
    def from_positions(cls, positions: np.ndarray, nbits: int) -> "BitVector":
        bits = np.zeros(nbits, dtype=np.uint8)
        bits[np.asarray(positions, dtype=np.int64)] = 1
        return cls(np.packbits(bits, bitorder="little"), nbits)

    def bits(self) -> np.ndarray:
        return np.unpackbits(self.data, bitorder="little")[: self.nbits]

    def one_positions(self) -> np.ndarray:
        return np.flatnonzero(self.bits()).astype(np.int64)

    # -- rank / select -----------------------------------------------------
    def rank1(self, pos: int) -> int:
        """Number of 1 bits in [0, pos)."""
        if pos <= 0:
            return 0
        pos = min(pos, self.nbits)
        byte, rem = divmod(pos, 8)
        r = int(self._byte_cum[byte])
        if rem:
            r += int(np.bitwise_count(self.data[byte] & ((1 << rem) - 1)))
        return r

    def rank1_batch(self, pos: np.ndarray) -> np.ndarray:
        pos = np.clip(np.asarray(pos, dtype=np.int64), 0, self.nbits)
        byte, rem = np.divmod(pos, 8)
        r = self._byte_cum[byte]
        partial = np.bitwise_count(
            self.data[np.minimum(byte, len(self.data) - 1)]
            & ((1 << rem.astype(np.uint8)) - 1).astype(np.uint8)
        ).astype(np.int64)
        return r + np.where(rem > 0, partial, 0)

    def rank0(self, pos: int) -> int:
        return min(pos, self.nbits) - self.rank1(pos)

    def select1(self, j: int) -> int:
        """Position of the j-th (0-based) 1 bit."""
        if not 0 <= j < self.nones:
            raise IndexError("select1 out of range")
        byte = int(np.searchsorted(self._byte_cum, j + 1, side="left")) - 1
        rem = j - int(self._byte_cum[byte])
        b = int(self.data[byte])
        for bit in range(8):
            if (b >> bit) & 1:
                if rem == 0:
                    return byte * 8 + bit
                rem -= 1
        raise AssertionError("select1 internal error")

    def select0(self, j: int) -> int:
        """Position of the j-th (0-based) 0 bit."""
        nzeros = self.nbits - self.nones
        if not 0 <= j < nzeros:
            raise IndexError("select0 out of range")
        # binary search on rank0(byte*8) = byte*8 - byte_cum[byte]
        zero_cum = np.arange(len(self._byte_cum), dtype=np.int64) * 8 - self._byte_cum
        byte = int(np.searchsorted(zero_cum, j + 1, side="left")) - 1
        rem = j - int(zero_cum[byte])
        b = int(self.data[byte])
        for bit in range(8):
            if not (b >> bit) & 1:
                if byte * 8 + bit >= self.nbits:
                    break
                if rem == 0:
                    return byte * 8 + bit
                rem -= 1
        raise AssertionError("select0 internal error")

    @property
    def size_bits(self) -> int:
        """Payload size (the raw bits), paper-comparable."""
        return self.nbits

    @property
    def index_bits(self) -> int:
        """Rank/select acceleration structures (sampled at _SUPER bytes)."""
        return 32 * (len(self._byte_cum) // _SUPER + 1)


def pack_lowbits(vals: np.ndarray, l: int) -> np.ndarray:
    """Pack the low ``l`` bits of each value into a little-endian bit stream."""
    if l == 0:
        return np.zeros(0, dtype=np.uint8)
    vals = np.asarray(vals, dtype=np.int64)
    bits = ((vals[:, None] >> np.arange(l)) & 1).astype(np.uint8).reshape(-1)
    return np.packbits(bits, bitorder="little")


def unpack_lowbits(
    packed: np.ndarray, l: int, n: int, start: int = 0, count: int | None = None
) -> np.ndarray:
    """Unpack ``count`` l-bit values starting at index ``start``."""
    if count is None:
        count = n - start
    if l == 0:
        return np.zeros(count, dtype=np.int64)
    bits = np.unpackbits(packed, bitorder="little", count=n * l)
    seg = bits[start * l : (start + count) * l].reshape(count, l).astype(np.int64)
    return (seg << np.arange(l)).sum(axis=1)
