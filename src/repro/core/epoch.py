"""Epoched id storage — O(Δ) online ingest for the paper's codecs.

Every codec in :mod:`repro.core.codecs` (and the joint wavelet tree)
encodes a list against a *fixed universe*: growing the id space from
``n`` to ``n + Δ`` changes every blob's rate and decode, which is why a
naive ``IVFIndex.add`` had to re-encode the entire index per append.

The epoch scheme decouples freshly-ingested data from the compacted
store (the "Decoupling Vector Data and Index Storage" architecture,
arXiv:2604.09173): each **epoch** owns a contiguous global-id range
``[base, base + count)`` and encodes its per-cluster id lists *relative
to its base* with universe ``count``.  Appending a batch of Δ vectors
creates one new epoch and touches nothing else — encoding work is
O(Δ), and previously-encoded epochs (including their wavelet trees)
are immutable until **compaction** folds all epochs back into a single
``[0, n)`` epoch, recovering the single-universe compression rate.

The logical per-cluster list is the concatenation of the per-epoch
lists in epoch order.  Because epoch ranges are ascending and disjoint
and each per-epoch list is sorted, the concatenation is *globally
sorted* — so storage order == sorted order, the invariant the batched
scanner's late id resolution (§4.1) and the sharded merge keys rely
on, holds across epochs by construction.

Shards reuse the scheme unchanged: a cluster shard keeps the global
epoch boundaries (``base``/``count`` are universe-wide) but only its
owned clusters' blobs — which are byte-identical to the monolithic
epoch's blobs, since both encode the same relative list against the
same universe.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .codecs import get_codec
from .wavelet_tree import WaveletTree

__all__ = ["Epoch", "EpochStore", "wt_sequence"]


def wt_sequence(lists: List[np.ndarray], n: int, nlist: int):
    """``(sequence, nsyms)`` for the wavelet tree over ``lists``.

    Monolithically the lists partition ``[0, n)`` and the sequence is the
    plain cluster-assignment string over ``nlist`` symbols.  A
    planner-made cluster shard covers only part of the universe: absent
    ids map to the sentinel symbol ``nlist`` (alphabet ``nlist + 1``),
    which no search ever selects on, so ``select(k, off)`` still returns
    ids for every owned cluster.  The rule is a pure function of
    ``(lists, n, nlist)`` — the planner and the RIDX loader apply it
    independently and agree, so ``id_bits()`` bookkeeping round-trips
    through save/load for shards too.
    """
    seq = np.full(n, nlist, np.int64)
    for k, lst in enumerate(lists):
        if len(lst):
            seq[lst] = k
    covered = int(sum(len(lst) for lst in lists))
    return seq, (nlist if covered == n else nlist + 1)


@dataclasses.dataclass
class Epoch:
    """One immutable ingest generation: ids in ``[base, base + count)``.

    ``sizes[k]`` counts the *locally held* members of cluster ``k`` (all
    of them monolithically, the owned subset on a shard).  ``blobs[k]``
    is cluster ``k``'s relative-id blob (stream codecs), or ``wt`` is the
    joint wavelet tree over the epoch's relative assignment string.
    """

    base: int
    count: int                               # relative universe of this epoch
    sizes: np.ndarray                        # (nlist,) int64 local counts
    blobs: Optional[List[object]] = None     # per-cluster codec blobs
    wt: Optional[WaveletTree] = None         # joint wt (ids=wt/wt1)

    @property
    def end(self) -> int:
        return self.base + self.count


class EpochStore:
    """Per-cluster id lists stored as a sequence of epochs.

    The owner (``IVFIndex`` / the shard planner / the RIDX loader) feeds
    it *relative, sorted* per-cluster lists per epoch; the store answers
    ``resolve`` queries over logical per-cluster offsets (the scanner's
    late-resolution pairs), reports ``id_bits`` across epochs, and
    rebuilds itself on ``compact``.
    """

    def __init__(self, nlist: int, id_codec: str):
        self.nlist = int(nlist)
        self.id_codec = id_codec
        self.is_wt = id_codec in ("wt", "wt1")
        self.codec = None if self.is_wt else get_codec(id_codec)
        self.epochs: List[Epoch] = []
        # (n_epochs + 1, nlist) cumulative per-cluster local counts: epoch e
        # holds logical offsets [cum[e, k], cum[e + 1, k]) of cluster k
        self._cum = np.zeros((1, self.nlist), np.int64)

    # -- bookkeeping ---------------------------------------------------------
    @property
    def n_epochs(self) -> int:
        return len(self.epochs)

    @property
    def end(self) -> int:
        """One past the largest id any epoch may hold (0 when empty)."""
        return self.epochs[-1].end if self.epochs else 0

    def id_bits(self) -> int:
        total = 0
        for ep in self.epochs:
            if self.is_wt:
                total += ep.wt.size_bits if ep.wt is not None else 0
            else:
                total += int(sum(self.codec.size_bits(b) for b in ep.blobs))
        return total

    # -- growth --------------------------------------------------------------
    def append(self, rel_lists: Sequence[np.ndarray], base: int,
               count: int) -> Epoch:
        """Seal one epoch: per-cluster *relative* sorted lists over
        universe ``count``, owning global range ``[base, base + count)``."""
        if base != self.end:
            raise ValueError(
                f"epoch base {base} does not extend the store (end "
                f"{self.end}); epochs must tile the id space")
        if count <= 0:
            raise ValueError("epoch count must be positive")
        if len(rel_lists) != self.nlist:
            raise ValueError(f"need one list per cluster ({self.nlist})")
        rel_lists = [np.asarray(lst, np.int64) for lst in rel_lists]
        sizes = np.array([len(lst) for lst in rel_lists], np.int64)
        if self.is_wt:
            seq, nsyms = wt_sequence(rel_lists, count, self.nlist)
            wt = WaveletTree.build(seq, nsyms,
                                   compressed=(self.id_codec == "wt1"))
            ep = Epoch(base=base, count=count, sizes=sizes, wt=wt)
        else:
            blobs = [self.codec.encode(lst, count) for lst in rel_lists]
            ep = Epoch(base=base, count=count, sizes=sizes, blobs=blobs)
        self.epochs.append(ep)
        self._cum = np.vstack([self._cum, self._cum[-1] + sizes])
        return ep

    def compact(self, lists: Sequence[np.ndarray], n: int) -> None:
        """Fold every epoch into one ``[0, n)`` epoch re-encoded from the
        *global* per-cluster lists (single-universe rates again).  The
        owner must invalidate its decoded-list cache afterwards — epoch
        indices restart at 0, so stale entries would alias."""
        self.epochs = []
        self._cum = np.zeros((1, self.nlist), np.int64)
        self.append([np.asarray(lst, np.int64) for lst in lists], 0, n)

    # -- derived views -------------------------------------------------------
    def rel_lists(self, e: int, lists: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Epoch ``e``'s relative per-cluster lists, sliced out of the
        *global* sorted lists (epoch members are contiguous in them)."""
        lo, hi = self._cum[e], self._cum[e + 1]
        base = self.epochs[e].base
        return [np.asarray(lists[k][lo[k]:hi[k]], np.int64) - base
                for k in range(self.nlist)]

    def split(self, mask: np.ndarray, lists: Sequence[np.ndarray]
              ) -> "EpochStore":
        """Shard view: owned clusters (``mask``) keep their blobs verbatim
        (same relative list, same universe -> same bytes), unowned ones
        hold an empty stream; wavelet trees rebuild per epoch with the
        sentinel rule.  Epoch boundaries stay global."""
        out = EpochStore(self.nlist, self.id_codec)
        for e, ep in enumerate(self.epochs):
            rel = self.rel_lists(e, lists)
            rel = [rel[k] if mask[k] else np.zeros(0, np.int64)
                   for k in range(self.nlist)]
            if self.is_wt:
                out.append(rel, ep.base, ep.count)
            else:
                sizes = np.where(mask, ep.sizes, 0).astype(np.int64)
                empty = self.codec.encode(np.zeros(0, np.int64), ep.count)
                blobs = [ep.blobs[k] if mask[k] else empty
                         for k in range(self.nlist)]
                sh = Epoch(base=ep.base, count=ep.count, sizes=sizes,
                           blobs=blobs)
                out.epochs.append(sh)
                out._cum = np.vstack([out._cum, out._cum[-1] + sizes])
        return out

    # -- queries -------------------------------------------------------------
    def resolve(self, clusters: np.ndarray, offsets: np.ndarray,
                cache) -> np.ndarray:
        """Logical ``(cluster, offset)`` pairs -> global ids.

        Offsets index the concatenated-across-epochs cluster list; each
        pair is routed to its epoch by a searchsorted over the per-cluster
        cumulative counts, then resolved inside the epoch — per-epoch
        decode through ``cache`` for stream codecs (keyed ``(epoch,
        cluster)``, so appends never invalidate warm entries), random
        ``gather`` for EF/compact/uncompressed, ``select`` for wavelet
        trees — and shifted by the epoch base.
        """
        clusters = np.asarray(clusters, np.int64)
        offsets = np.asarray(offsets, np.int64)
        out = np.empty(clusters.shape[0], np.int64)
        if clusters.shape[0] == 0:
            return out
        order = np.argsort(clusters, kind="stable")
        bounds = np.flatnonzero(np.diff(clusters[order])) + 1
        for grp in np.split(order, bounds):
            k = int(clusters[grp[0]])
            offs = offsets[grp]
            cum_k = self._cum[:, k]
            e_idx = np.searchsorted(cum_k, offs, side="right") - 1
            for e in np.unique(e_idx):
                ep = self.epochs[int(e)]
                sel = e_idx == e
                rel = offs[sel] - cum_k[e]
                if self.is_wt:
                    vals = ep.wt.select_batch([k] * int(sel.sum()), rel)
                else:
                    blob = ep.blobs[k]
                    vals = self.codec.gather(blob, rel)
                    if vals is None:
                        ids_rel = cache.get(
                            (int(e), k),
                            lambda: np.asarray(
                                self.codec.decode(blob, ep.count)))
                        vals = ids_rel[rel]
                out[grp[sel]] = np.asarray(vals, np.int64) + ep.base
        return out
