"""RRR-style compressed bitvector (class/offset enumerative coding).

Backs the paper's ``WT1`` variant (Raman-Raman-Rao [46] as used by SDSL's
``rrr_vector``): the bitvector is cut into B=31-bit blocks; each block
stores its *class* c = popcount (5 bits, fixed width) and its *offset* —
the enumerative rank of the block's pattern among all C(31, c) patterns —
in ``ceil(log2 C(31, c))`` bits.  Biased blocks (c near 0 or 31) cost ~0
offset bits, which is where the compression over a flat bitvector comes
from; perfectly balanced blocks cost slightly more than 1 bit/bit.
Superblock samples (rank + offset-stream position every 16 blocks) give
O(1)-ish rank; they are counted in ``index_bits``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["RRRVector"]

_B = 31                 # block size in bits
_CLASS_BITS = 5
_SUPER = 16             # blocks per superblock

# Pascal triangle up to 31; C[n, k]
_C = np.zeros((_B + 1, _B + 1), dtype=np.int64)
_C[:, 0] = 1
for _n in range(1, _B + 1):
    for _k in range(1, _n + 1):
        _C[_n, _k] = _C[_n - 1, _k - 1] + _C[_n - 1, _k]

# offset bit-width per class
_W = np.array(
    [int(np.ceil(np.log2(max(1, int(_C[_B, c]))))) for c in range(_B + 1)],
    dtype=np.int64,
)


def _encode_offsets(blocks: np.ndarray, classes: np.ndarray) -> np.ndarray:
    """Enumerative rank of each block pattern within its class (vectorized)."""
    nblk = blocks.shape[0]
    offsets = np.zeros(nblk, dtype=np.int64)
    remaining_ones = classes.copy()
    # msb-first scan: positions b = B-1 .. 0, 'remaining positions' = b
    for b in range(_B - 1, -1, -1):
        bit = (blocks >> b) & 1
        # C(b, rem) = #patterns with a 0 at position b (rem ones in b slots);
        # the table is zero for rem > b, which is exactly the right value.
        offsets += np.where(bit == 1, _C[b, remaining_ones], 0)
        remaining_ones -= bit
    return offsets


def _decode_block(offset: int, c: int) -> int:
    """Inverse of :func:`_encode_offsets` for a single block."""
    pattern = 0
    rem = c
    for b in range(_B - 1, -1, -1):
        if rem == 0:
            break
        take = int(_C[b, rem])  # zero when rem > b => bit must be 1
        if offset >= take:
            offset -= take
            pattern |= 1 << b
            rem -= 1
    return pattern


@dataclasses.dataclass
class RRRVector:
    nbits: int
    classes: np.ndarray      # (nblocks,) uint8
    offsets: np.ndarray      # (nblocks,) int64 — offset values (packed width _W[c])
    rank_samples: np.ndarray # (nsuper+1,) cumulative ones before superblock

    @classmethod
    def from_bits(cls, bits: np.ndarray) -> "RRRVector":
        bits = np.asarray(bits, dtype=np.uint8)
        nbits = int(bits.size)
        nblk = -(-nbits // _B) if nbits else 0
        padded = np.zeros(nblk * _B, dtype=np.uint8)
        padded[:nbits] = bits
        words = padded.reshape(nblk, _B).astype(np.int64)
        blocks = (words << np.arange(_B)).sum(axis=1)  # bit b of block = position b
        classes = np.bitwise_count(blocks.astype(np.uint64)).astype(np.int64)
        offsets = _encode_offsets(blocks, classes)
        nsuper = -(-nblk // _SUPER) if nblk else 0
        cum = np.concatenate([[0], np.cumsum(classes)]).astype(np.int64)
        rank_samples = cum[np.minimum(np.arange(nsuper + 1) * _SUPER, nblk)]
        return cls(
            nbits=nbits,
            classes=classes.astype(np.uint8),
            offsets=offsets,
            rank_samples=rank_samples,
        )

    # -- queries -----------------------------------------------------------
    def _block_pattern(self, blk: int) -> int:
        return _decode_block(int(self.offsets[blk]), int(self.classes[blk]))

    def rank1(self, pos: int) -> int:
        if pos <= 0:
            return 0
        pos = min(pos, self.nbits)
        blk, rem = divmod(pos, _B)
        sup = blk // _SUPER
        r = int(self.rank_samples[sup])
        lo = sup * _SUPER
        if blk > lo:
            r += int(self.classes[lo:blk].astype(np.int64).sum())
        if rem:
            pat = self._block_pattern(blk) if blk < len(self.classes) else 0
            r += int(np.bitwise_count(np.uint64(pat & ((1 << rem) - 1))))
        return r

    def rank0(self, pos: int) -> int:
        return min(pos, self.nbits) - self.rank1(pos)

    @property
    def nones(self) -> int:
        return int(self.rank_samples[-1]) + (
            int(self.classes[(len(self.rank_samples) - 1) * _SUPER :].astype(np.int64).sum())
            if (len(self.rank_samples) - 1) * _SUPER < len(self.classes)
            else 0
        )

    def _select_generic(self, j: int, ones: bool) -> int:
        total = self.nones if ones else self.nbits - self.nones
        if not 0 <= j < total:
            raise IndexError("select out of range")
        # binary search superblocks
        if ones:
            samples = self.rank_samples
        else:
            samples = (
                np.arange(len(self.rank_samples), dtype=np.int64) * _SUPER * _B
                - self.rank_samples
            )
        sup = int(np.searchsorted(samples, j + 1, side="left")) - 1
        blk = sup * _SUPER
        acc = int(samples[sup])
        # scan blocks
        while blk < len(self.classes):
            c = int(self.classes[blk])
            inblk = c if ones else min(_B, self.nbits - blk * _B) - c
            if acc + inblk > j:
                break
            acc += inblk
            blk += 1
        pat = self._block_pattern(blk)
        rem = j - acc
        for b in range(_B):
            bit = (pat >> b) & 1
            if (bit == 1) == ones:
                if rem == 0:
                    return blk * _B + b
                rem -= 1
        raise AssertionError("select internal error")

    def select1(self, j: int) -> int:
        return self._select_generic(j, True)

    def select0(self, j: int) -> int:
        return self._select_generic(j, False)

    def bits(self) -> np.ndarray:
        out = np.zeros(len(self.classes) * _B, dtype=np.uint8)
        for blk in range(len(self.classes)):
            pat = self._block_pattern(blk)
            for b in range(_B):
                out[blk * _B + b] = (pat >> b) & 1
        return out[: self.nbits]

    # -- sizes ---------------------------------------------------------------
    @property
    def size_bits(self) -> int:
        """Payload: 5-bit classes + variable-width offsets."""
        return _CLASS_BITS * len(self.classes) + int(_W[self.classes].sum())

    @property
    def index_bits(self) -> int:
        # rank sample (u32) + offset-stream pointer (u32) per superblock
        return 64 * len(self.rank_samples)
