"""Random Order Coding (ROC) — bits-back coding of id *sets*.

This is the paper's primary codec (Section 3.2 / 4.2).  A cluster's id list
is order-invariant, so a sequence of ``n`` unique ids drawn from ``[N)``
carries ``log n!`` fewer bits than its naive encoding.  ROC collects exactly
that saving with an ANS stack:

encode (per cluster, ids need not be pre-sorted)::

    for i = n .. 1:                       # i = number of ids remaining
        j   = ans.pop_uniform(i)          # bits-back: sample a rank (-log i bits)
        x   = j-th smallest remaining id  # order statistics (Fenwick)
        ans.push_uniform(x, N)            # id model: uniform over [N)  (+log N bits)

decode::

    for i = 1 .. n:
        x = ans.pop_uniform(N)
        j = rank of x among ids decoded so far (after insertion)
        ans.push_uniform(j, i)            # return the borrowed bits

Both loops are exact mirrors, so the ANS state round-trips exactly; with the
exact big-integer coder (``BigANS``) the rate is ``log2 C(N, n)`` up to +1
bit, with **no initial-bits overhead**: starting from state 0, early
``pop_uniform`` calls on a small state are still bijective (they return
low-entropy ranks), which is the cleanest resolution of the paper's
"initial bits issue" for the offline/online settings alike.

Differences from the paper's C++ implementation (documented in DESIGN.md):
the paper uses a fixed-width streaming ANS where the initial state is filled
with random bits; we use the exact coder for rate reporting (the paper notes
ANS redundancy is ~2e-5 bits/op — unobservable at our scales) and the
vectorized lane coder (``repro.core.gap_ans``) for the TPU-adapted fast path.
"""

from __future__ import annotations

import bisect
from typing import List, Sequence

import numpy as np

from .ans import BigANS
from .fenwick import Fenwick

__all__ = [
    "roc_push_set",
    "roc_pop_set",
    "roc_encode_clusters",
    "roc_decode_clusters",
    "set_information_bits",
]


def roc_push_set(ans: BigANS, ids: Sequence[int], alphabet: int) -> None:
    """Push the *set* of unique ``ids`` (subset of ``[alphabet)``) onto ``ans``."""
    sorted_ids = np.sort(np.asarray(ids, dtype=np.int64))
    n = int(sorted_ids.size)
    if n == 0:
        return
    if sorted_ids[0] < 0 or sorted_ids[-1] >= alphabet:
        raise ValueError("ids out of range")
    if n > 1 and np.any(sorted_ids[1:] == sorted_ids[:-1]):
        raise ValueError("ROC set codec requires unique ids")
    ids_list = [int(v) for v in sorted_ids]
    if n <= 512:
        # O(n^2) memmove path: faster than Fenwick for small clusters.
        for i in range(n, 0, -1):
            j = ans.pop_uniform(i)
            x = ids_list.pop(j)
            ans.push_uniform(x, alphabet)
    else:
        fw = Fenwick.ones(n)
        for i in range(n, 0, -1):
            j = ans.pop_uniform(i)
            pos = fw.find(j)
            fw.add(pos, -1)
            ans.push_uniform(ids_list[pos], alphabet)


def roc_pop_set(ans: BigANS, n: int, alphabet: int) -> np.ndarray:
    """Pop a set of ``n`` ids; returns them sorted ascending."""
    out: List[int] = []
    for i in range(1, n + 1):
        x = ans.pop_uniform(alphabet)
        j = bisect.bisect_left(out, x)
        out.insert(j, x)
        ans.push_uniform(j, i)
    return np.asarray(out, dtype=np.int64)


def roc_encode_clusters(
    lists: Sequence[np.ndarray], alphabet: int, joint: bool = False
) -> List[BigANS]:
    """Encode inverted lists.

    ``joint=False`` — the paper's *online* setting: one stream per cluster
    (partial random access).  ``joint=True`` — the *offline* setting: all
    clusters share one stream (decoded back-to-front), amortizing nothing
    here (BigANS has no initial bits) but producing a single blob.
    """
    if joint:
        ans = BigANS()
        for ids in lists:
            roc_push_set(ans, ids, alphabet)
        return [ans]
    return [_encode_one(ids, alphabet) for ids in lists]


def _encode_one(ids: np.ndarray, alphabet: int) -> BigANS:
    ans = BigANS()
    roc_push_set(ans, ids, alphabet)
    return ans


def roc_decode_clusters(
    streams: Sequence[BigANS], sizes: Sequence[int], alphabet: int, joint: bool = False
) -> List[np.ndarray]:
    if joint:
        (ans,) = streams
        out = [roc_pop_set(ans, n, alphabet) for n in reversed(list(sizes))]
        return out[::-1]
    return [roc_pop_set(a, n, alphabet) for a, n in zip(streams, sizes)]


def set_information_bits(alphabet: int, n: int) -> float:
    """``log2 C(alphabet, n)`` — the information content of an n-subset."""
    import math

    return (
        math.lgamma(alphabet + 1)
        - math.lgamma(n + 1)
        - math.lgamma(alphabet - n + 1)
    ) / math.log(2)
