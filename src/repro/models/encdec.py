"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frame frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d_model).  The encoder is
a bidirectional transformer; the decoder adds cross-attention over the
encoder memory.  Decode shapes cache (a) decoder self-attention KV and
(b) the projected encoder memory KV (computed once at prefill).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import KVCache, attention, decode_attention, init_attention, init_cache
from .layers import (
    dense,
    embed,
    init_dense,
    init_embedding,
    init_mlp,
    init_rms_norm,
    mlp,
    rms_norm,
    unembed,
)

__all__ = ["init_encdec", "encdec_apply", "encdec_encode", "encdec_decode",
           "init_encdec_cache", "dec_len_for"]


def _remat_policy(cfg):
    """Remat policy from the config (§Perf hillclimb #3)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def dec_len_for(seq_len: int) -> int:
    """Decoder length for training shapes: seq/4 (frames >> tokens)."""
    return max(1, seq_len // 4)


def _init_cross(key, cfg):
    hd = cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, cfg.d_model, cfg.n_heads * hd),
        "wk": init_dense(kk, cfg.d_model, cfg.n_kv_heads * hd),
        "wv": init_dense(kv, cfg.d_model, cfg.n_kv_heads * hd),
        "wo": init_dense(ko, cfg.n_heads * hd, cfg.d_model),
    }


def _cross_kv(params, memory, cfg):
    B, T, _ = memory.shape
    hd = cfg.head_dim_
    k = dense(params["wk"], memory).reshape(B, T, cfg.n_kv_heads, hd)
    v = dense(params["wv"], memory).reshape(B, T, cfg.n_kv_heads, hd)
    return k, v


def _cross_attend(params, x, mem_k, mem_v, cfg):
    from .attention import _BLOCK_THRESHOLD, _sdpa, _sdpa_blocked

    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = dense(params["wq"], x).reshape(B, S, cfg.n_heads, hd)
    if mem_k.shape[1] > _BLOCK_THRESHOLD and S > 1:
        out = _sdpa_blocked(q, mem_k, mem_v, cfg, causal=False)
    else:
        out = _sdpa(q, mem_k, mem_v, None, cfg)
    return dense(params["wo"], out.reshape(B, S, -1))


def _init_enc_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": init_rms_norm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rms_norm(cfg.d_model),
        "self_attn": init_attention(k1, cfg),
        "ln_x": init_rms_norm(cfg.d_model),
        "cross": _init_cross(k2, cfg),
        "ln2": init_rms_norm(cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_encdec(key, cfg: ModelConfig) -> Dict[str, Any]:
    ke, kd, kt = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": init_embedding(kt, cfg.padded_vocab, cfg.d_model),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_norm": init_rms_norm(cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "final_norm": init_rms_norm(cfg.d_model),
    }


def encdec_encode(params, cfg, frames, remat: bool = True,
                  unroll: bool = False):
    """frames (B, S_enc, d_model) -> encoder memory."""
    B, S, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, p):
        a = attention(p["attn"], rms_norm(p["ln1"], h, cfg.norm_eps),
                      positions, cfg, causal=False)
        h = h + a
        return h + mlp(p["mlp"], rms_norm(p["ln2"], h, cfg.norm_eps)), None

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg),
                              prevent_cse=False)
    if unroll:
        for i in range(cfg.n_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def encdec_apply(params, cfg, frames, dec_tokens, remat: bool = True,
                 unroll: bool = False):
    """Training/prefill forward -> (logits (B, S_dec, V), aux 0)."""
    memory = encdec_encode(params, cfg, frames, remat=remat, unroll=unroll)
    B, S = dec_tokens.shape
    x = embed(params["embed"], dec_tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, p):
        a = attention(p["self_attn"], rms_norm(p["ln1"], h, cfg.norm_eps),
                      positions, cfg, causal=True)
        h = h + a
        mk, mv = _cross_kv(p["cross"], memory, cfg)
        h = h + _cross_attend(p["cross"], rms_norm(p["ln_x"], h, cfg.norm_eps),
                              mk, mv, cfg)
        return h + mlp(p["mlp"], rms_norm(p["ln2"], h, cfg.norm_eps)), None

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg),
                              prevent_cse=False)
    if unroll:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["dec_blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x), jnp.zeros((), jnp.float32)


class EncDecCache(NamedTuple):
    self_kv: Any          # stacked per-layer KVCache
    mem_k: jnp.ndarray    # (L, B, T, KV, hd) projected encoder memory
    mem_v: jnp.ndarray


def init_encdec_cache(batch: int, max_len: int, cfg, dtype=jnp.bfloat16,
                      mem_len: int | None = None) -> EncDecCache:
    mem_len = mem_len or max_len
    hd = cfg.head_dim_
    L = cfg.n_layers
    kv = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_cache(batch, max_len, cfg, dtype) for _ in range(L)])
    shape = (L, batch, mem_len, cfg.n_kv_heads, hd)
    return EncDecCache(
        self_kv=kv,
        mem_k=jnp.zeros(shape, dtype),
        mem_v=jnp.zeros(shape, dtype),
    )


def encdec_prefill_memory(params, cfg, frames, cache: EncDecCache) -> EncDecCache:
    """Run the encoder once and stash per-layer projected cross KV."""
    memory = encdec_encode(params, cfg, frames, remat=False)

    def proj(p):
        return _cross_kv({"wk": p["cross"]["wk"], "wv": p["cross"]["wv"]},
                         memory, cfg)

    mk, mv = jax.vmap(proj)(params["dec_blocks"])
    return cache._replace(mem_k=mk.astype(cache.mem_k.dtype),
                          mem_v=mv.astype(cache.mem_v.dtype))


def encdec_decode(params, cfg, cache: EncDecCache, token,
                  unroll: bool = False):
    """One decoder token step against cached self-KV + encoder memory."""
    x = embed(params["embed"], token).astype(jnp.dtype(cfg.dtype))

    def body(h, pc):
        p, kv, mk, mv = pc
        a, kv = decode_attention(p["self_attn"],
                                 rms_norm(p["ln1"], h, cfg.norm_eps), kv, cfg)
        h = h + a
        h = h + _cross_attend(p["cross"], rms_norm(p["ln_x"], h, cfg.norm_eps),
                              mk, mv, cfg)
        h = h + mlp(p["mlp"], rms_norm(p["ln2"], h, cfg.norm_eps))
        return h, kv

    xs = (params["dec_blocks"], cache.self_kv, cache.mem_k, cache.mem_v)
    if unroll:
        outs = []
        for i in range(cfg.n_layers):
            x, kv_i = body(x, jax.tree.map(lambda a: a[i], xs))
            outs.append(kv_i)
        new_kv = jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
    else:
        x, new_kv = jax.lax.scan(body, x, xs)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x), cache._replace(self_kv=new_kv)
