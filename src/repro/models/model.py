"""Unified model facade: build(cfg) -> Model(init/apply/decode/cache/specs).

``input_specs(cfg, shape, kind)`` returns ShapeDtypeStruct stand-ins for
every model input (the dry-run contract): tokens for text archs,
precomputed frame/patch embeddings for the stubbed audio/vision frontends,
3-stream positions for M-RoPE.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeSpec
from . import encdec as _encdec
from . import transformer as _tf

__all__ = ["Model", "build", "input_specs", "count_params", "model_flops"]


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]
    apply: Callable[..., Any]          # (params, **batch) -> (logits, aux)
    decode_step: Callable[..., Any]    # (params, cache, **inputs) -> (logits, cache)
    init_cache: Callable[..., Any]     # (batch, max_len, dtype) -> cache


def build(cfg: ModelConfig) -> Model:
    if cfg.encoder_decoder:
        def apply_fn(params, frames=None, dec_tokens=None, remat=True,
                     unroll=False, **_):
            return _encdec.encdec_apply(params, cfg, frames, dec_tokens,
                                        remat=remat, unroll=unroll)

        def decode_fn(params, cache, token=None, unroll=False, **_):
            return _encdec.encdec_decode(params, cfg, cache, token, unroll=unroll)

        def cache_fn(batch, max_len, dtype=jnp.bfloat16, mem_len=None):
            return _encdec.init_encdec_cache(batch, max_len, cfg, dtype, mem_len)

        return Model(cfg, lambda key: _encdec.init_encdec(key, cfg),
                     apply_fn, decode_fn, cache_fn)

    def apply_fn(params, tokens=None, embeddings=None, positions=None,
                 remat=True, unroll=False, **_):
        return _tf.decoder_apply(params, cfg, tokens=tokens,
                                 embeddings=embeddings, positions=positions,
                                 remat=remat, unroll=unroll)

    def decode_fn(params, cache, token=None, embedding=None, unroll=False, **_):
        return _tf.decoder_decode(params, cfg, cache, token=token,
                                  embedding=embedding, unroll=unroll)

    def cache_fn(batch, max_len, dtype=jnp.bfloat16, **_):
        return _tf.init_decoder_cache(batch, max_len, cfg, dtype)

    return Model(cfg, lambda key: _tf.init_decoder(key, cfg),
                 apply_fn, decode_fn, cache_fn)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Inputs for train/prefill; decode uses ``decode_input_specs``."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    if cfg.encoder_decoder:
        Sd = _encdec.dec_len_for(S)
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
            "dec_tokens": jax.ShapeDtypeStruct((B, Sd), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, Sd), jnp.int32),
        }
    if cfg.frontend == "vision":
        return {
            "embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
            "positions": jax.ShapeDtypeStruct((3, B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    f32 = jnp.dtype(cfg.dtype)
    if cfg.encoder_decoder:
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.frontend == "vision":
        return {"embedding": jax.ShapeDtypeStruct((B, 1, cfg.d_model), f32)}
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# parameter / FLOP accounting (for rooflines)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact count via eval_shape on init (no allocation)."""
    model = build(cfg)
    tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0
    expert_extra = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        n = int(np.prod(leaf.shape))
        total += n
        names = "/".join(str(p) for p in path)
        if "moe" in names and leaf.ndim >= 3 and leaf.shape[-3] == cfg.n_experts:
            expert_extra += n
    if active_only and cfg.n_experts:
        k = cfg.experts_per_token
        total -= expert_extra
        total += int(expert_extra * k / cfg.n_experts)
    return total


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per §Roofline."""
    n = count_params(cfg, active_only=bool(cfg.n_experts))
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
        if cfg.encoder_decoder:
            # decoder tokens carry the 6ND; encoder counted via its params
            tokens = shape.global_batch * _encdec.dec_len_for(shape.seq_len)
    if shape.kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens  # inference: forward only
