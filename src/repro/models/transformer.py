"""Decoder-stack assembly for all assigned families.

Layer stacks are grouped into *segments* of identical repeating
"super-blocks" and executed with ``lax.scan`` over stacked params — compile
time is O(#distinct block bodies), not O(depth) (80-layer qwen2-72b lowers
as one scanned body).  Heterogeneous patterns become super-blocks:

    gemma3-1b   [(5 local + 1 global) x 4, local x 2]
    zamba2-2.7b [(5 mamba + 1 mamba+shared-attn) x 9]   (shared weights + LoRA)
    xlstm-1.3b  [(5 mLSTM + 1 sLSTM) x 8]
    moe archs   [moe-block x L]
    dense       [block x L]

Decode threads a per-segment stacked cache through the same scans.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (
    KVCache,
    attention,
    decode_attention,
    init_attention,
    init_cache,
)
from .layers import (
    _he,
    dense,
    embed,
    init_dense,
    init_embedding,
    init_mlp,
    init_rms_norm,
    mlp,
    rms_norm,
    unembed,
)
from .moe import init_moe, moe_apply
from .ssm import (
    MambaCache,
    init_mamba,
    init_mamba_cache,
    mamba_apply,
    mamba_decode,
)
from .xlstm import (
    MLstmCache,
    SLstmCache,
    init_mlstm,
    init_mlstm_cache,
    init_slstm,
    init_slstm_cache,
    mlstm_apply,
    mlstm_decode,
    slstm_apply,
    slstm_decode,
)

__all__ = ["segments_for", "init_decoder", "decoder_apply", "decoder_decode",
           "init_decoder_cache"]


def _remat_policy(cfg):
    """Remat policy from the config (§Perf hillclimb #3)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable

_LORA_RANK = 128


# ---------------------------------------------------------------------------
# segment layout
# ---------------------------------------------------------------------------

def segments_for(cfg: ModelConfig) -> List[Tuple[str, int, int]]:
    """[(super_block_kind, n_iterations, layers_per_super), ...]."""
    if cfg.family in ("dense",) and cfg.local_global_ratio:
        per = cfg.local_global_ratio + 1
        n_super = cfg.n_layers // per
        rem = cfg.n_layers - n_super * per
        segs = [("local_global", n_super, per)]
        if rem:
            segs.append(("local_only", rem, 1))
        return segs
    if cfg.family == "hybrid":
        per = cfg.hybrid_attn_every
        assert cfg.n_layers % per == 0
        return [("mamba_hybrid", cfg.n_layers // per, per)]
    if cfg.family == "ssm" and cfg.mlstm_slstm_pattern:
        per = cfg.mlstm_slstm_pattern + 1
        assert cfg.n_layers % per == 0
        return [("xlstm_super", cfg.n_layers // per, per)]
    if cfg.family == "moe":
        return [("moe_block", cfg.n_layers, 1)]
    return [("dense_block", cfg.n_layers, 1)]


# ---------------------------------------------------------------------------
# single blocks
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": init_rms_norm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _dense_block(params, x, positions, cfg, window: int = 0):
    h = x + attention(params["attn"], rms_norm(params["ln1"], x, cfg.norm_eps),
                      positions, cfg, window=window)
    return h + mlp(params["mlp"], rms_norm(params["ln2"], h, cfg.norm_eps))


def _dense_block_decode(params, x, cache: KVCache, cfg, window: int = 0):
    a, cache = decode_attention(
        params["attn"], rms_norm(params["ln1"], x, cfg.norm_eps), cache, cfg,
        window=window)
    h = x + a
    return h + mlp(params["mlp"], rms_norm(params["ln2"], h, cfg.norm_eps)), cache


def _init_moe_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": init_rms_norm(cfg.d_model),
        "moe": init_moe(k2, cfg),
    }


def _moe_block(params, x, positions, cfg):
    h = x + attention(params["attn"], rms_norm(params["ln1"], x, cfg.norm_eps),
                      positions, cfg)
    y, aux = moe_apply(params["moe"], rms_norm(params["ln2"], h, cfg.norm_eps), cfg)
    return h + y, aux


def _moe_block_decode(params, x, cache: KVCache, cfg):
    a, cache = decode_attention(
        params["attn"], rms_norm(params["ln1"], x, cfg.norm_eps), cache, cfg)
    h = x + a
    y, _ = moe_apply(params["moe"], rms_norm(params["ln2"], h, cfg.norm_eps), cfg)
    return h + y, cache


def _init_mamba_block(key, cfg):
    return {"ln": init_rms_norm(cfg.d_model), "mixer": init_mamba(key, cfg)}


def _mamba_block(params, x, cfg):
    return x + mamba_apply(params["mixer"], rms_norm(params["ln"], x, cfg.norm_eps), cfg)


def _mamba_block_decode(params, x, cache: MambaCache, cfg):
    y, cache = mamba_decode(params["mixer"], rms_norm(params["ln"], x, cfg.norm_eps),
                            cache, cfg)
    return x + y, cache


# ---------------------------------------------------------------------------
# super-blocks (params for one scan iteration)
# ---------------------------------------------------------------------------

def _init_super(key, kind: str, cfg, per: int):
    ks = jax.random.split(key, per + 1)
    if kind == "dense_block":
        return _init_dense_block(ks[0], cfg)
    if kind == "local_only":
        return _init_dense_block(ks[0], cfg)
    if kind == "moe_block":
        return _init_moe_block(ks[0], cfg)
    if kind == "local_global":
        return {
            "locals": jax.vmap(lambda k: _init_dense_block(k, cfg))(
                jnp.stack(ks[: per - 1])),
            "global": _init_dense_block(ks[per - 1], cfg),
        }
    if kind == "mamba_hybrid":
        p = {
            "mambas": jax.vmap(lambda k: _init_mamba_block(k, cfg))(
                jnp.stack(ks[:per])),
            # per-use LoRA adapter modulating the shared attention input
            "lora_a": _he(ks[per], (cfg.d_model, _LORA_RANK), cfg.d_model),
            "lora_b": jnp.zeros((_LORA_RANK, cfg.d_model), jnp.float32),
        }
        return p
    if kind == "xlstm_super":
        def _one_mlstm(k):
            return {"ln": init_rms_norm(cfg.d_model), "core": init_mlstm(k, cfg)}

        return {
            "mlstms": jax.vmap(_one_mlstm)(jnp.stack(ks[: per - 1])),
            "slstm": {"ln": init_rms_norm(cfg.d_model),
                      "core": init_slstm(ks[per - 1], cfg)},
        }
    raise ValueError(kind)


def _unscan(body, x, stacked, n):
    """Python-loop replacement for lax.scan (cost-probe mode)."""
    for i in range(n):
        x, _ = body(x, jax.tree.map(lambda a: a[i], stacked))
    return x


def _apply_super(kind, params, x, positions, cfg, shared, per, unroll=False):
    """Forward one super-block; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense_block", "local_only"):
        w = cfg.sliding_window if (kind == "local_only" or
                                   (kind == "dense_block" and cfg.sliding_window and
                                    not cfg.local_global_ratio)) else 0
        return _dense_block(params, x, positions, cfg, window=w), aux
    if kind == "moe_block":
        x, aux = _moe_block(params, x, positions, cfg)
        return x, aux
    if kind == "local_global":
        def body(h, p):
            return _dense_block(p, h, positions, cfg, window=cfg.sliding_window), None
        x = _unscan(body, x, params["locals"], per - 1) if unroll else \
            jax.lax.scan(body, x, params["locals"])[0]
        x = _dense_block(params["global"], x, positions, cfg, window=0)
        return x, aux
    if kind == "mamba_hybrid":
        def body(h, p):
            return _mamba_block(p, h, cfg), None
        x = _unscan(body, x, params["mambas"], per) if unroll else \
            jax.lax.scan(body, x, params["mambas"])[0]
        # shared attention block with per-use LoRA input adaptation
        adapt = (x @ params["lora_a"].astype(x.dtype)) @ params["lora_b"].astype(x.dtype)
        x = _dense_block(shared["block"], x + adapt, positions, cfg)
        return x, aux
    if kind == "xlstm_super":
        def body(h, p):
            return h + mlstm_apply(p["core"], rms_norm(p["ln"], h, cfg.norm_eps), cfg), None
        x = _unscan(body, x, params["mlstms"], per - 1) if unroll else \
            jax.lax.scan(body, x, params["mlstms"])[0]
        x = x + slstm_apply(
            params["slstm"]["core"],
            rms_norm(params["slstm"]["ln"], x, cfg.norm_eps), cfg)
        return x, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full decoder
# ---------------------------------------------------------------------------

def init_decoder(key, cfg: ModelConfig) -> Dict[str, Any]:
    segs = segments_for(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params: Dict[str, Any] = {}
    if cfg.frontend is None:
        params["embed"] = init_embedding(keys[0], cfg.padded_vocab, cfg.d_model)
    else:
        # frontend stub: inputs arrive as embeddings; separate output head
        params["embed"] = init_embedding(keys[0], cfg.padded_vocab, cfg.d_model)
    params["segments"] = []
    for i, (kind, n_iter, per) in enumerate(segs):
        sub = jax.random.split(keys[i + 1], n_iter)
        params["segments"].append(
            jax.vmap(lambda k: _init_super(k, kind, cfg, per))(jnp.stack(sub))
        )
    if cfg.family == "hybrid":
        params["shared_attn"] = {"block": _init_dense_block(keys[-2], cfg)}
    params["final_norm"] = init_rms_norm(cfg.d_model)
    return params


def _needs_mlstm_ln(cfg):
    return cfg.family == "ssm" and cfg.mlstm_slstm_pattern


def init_mlstm_block_extra(p, cfg):  # pragma: no cover - helper for init only
    return p


def decoder_apply(params, cfg: ModelConfig, tokens=None, embeddings=None,
                  positions=None, remat: bool = True, unroll: bool = False):
    """Forward pass -> (logits (B,S,V), aux_loss).

    ``unroll=True`` replaces the layer scans with Python loops — used by the
    dry-run cost probes, where XLA's cost_analysis counts while-loop bodies
    once (see benchmarks/roofline.py).
    """
    if embeddings is None:
        x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        B, S = tokens.shape
    else:
        x = embeddings.astype(jnp.dtype(cfg.dtype))
        B, S = embeddings.shape[:2]
    if positions is None:
        base = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = (
            jnp.broadcast_to(base[None], (3, B, S))
            if cfg.mrope_sections is not None else base
        )
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    for (kind, n_iter, per), seg_params in zip(segments_for(cfg), params["segments"]):
        def body(h, p, _kind=kind, _per=per):
            out, aux = _apply_super(_kind, p, h, positions, cfg, shared, _per,
                                    unroll=unroll)
            return out, aux
        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg),
                                  prevent_cse=False)
        if unroll:
            for i in range(n_iter):
                p_i = jax.tree.map(lambda a: a[i], seg_params)
                x, aux = body(x, p_i)
                aux_total = aux_total + aux
        else:
            def scan_body(h, p):
                out, aux = body(h, p)
                return out, aux
            x, auxs = jax.lax.scan(scan_body, x, seg_params)
            aux_total = aux_total + auxs.sum()
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, aux_total


# -- decode -------------------------------------------------------------------

def _init_super_cache(kind, batch, max_len, cfg, per, dtype):
    if kind in ("dense_block", "local_only", "moe_block"):
        w = cfg.sliding_window if kind == "local_only" else 0
        eff = min(max_len, w) if w else max_len
        return init_cache(batch, eff, cfg, dtype)
    if kind == "local_global":
        w = min(max_len, cfg.sliding_window)
        return {
            "locals": _stack_caches(
                [init_cache(batch, w, cfg, dtype) for _ in range(per - 1)]),
            "global": init_cache(batch, max_len, cfg, dtype),
        }
    if kind == "mamba_hybrid":
        return {
            "mambas": _stack_caches(
                [init_mamba_cache(batch, cfg) for _ in range(per)]),
            "attn": init_cache(batch, max_len, cfg, dtype),
        }
    if kind == "xlstm_super":
        return {
            "mlstms": _stack_caches(
                [init_mlstm_cache(batch, cfg) for _ in range(per - 1)]),
            "slstm": init_slstm_cache(batch, cfg),
        }
    raise ValueError(kind)


def _stack_caches(caches):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def init_decoder_cache(batch: int, max_len: int, cfg: ModelConfig, dtype=jnp.bfloat16):
    segs = segments_for(cfg)
    return [
        _stack_caches(
            [_init_super_cache(kind, batch, max_len, cfg, per, dtype)
             for _ in range(n_iter)])
        for kind, n_iter, per in segs
    ]


def _decode_super(kind, params, x, cache, cfg, shared, per):
    if kind in ("dense_block", "local_only"):
        w = cfg.sliding_window if kind == "local_only" else 0
        return _dense_block_decode(params, x, cache, cfg, window=w)
    if kind == "moe_block":
        return _moe_block_decode(params, x, cache, cfg)
    if kind == "local_global":
        def body(h, pc):
            p, c = pc
            h, c = _dense_block_decode(p, h, c, cfg, window=cfg.sliding_window)
            return h, c
        x, lc = jax.lax.scan(body, x, (params["locals"], cache["locals"]))
        x, gc = _dense_block_decode(params["global"], x, cache["global"], cfg)
        return x, {"locals": lc, "global": gc}
    if kind == "mamba_hybrid":
        def body(h, pc):
            p, c = pc
            h, c = _mamba_block_decode(p, h, c, cfg)
            return h, c
        x, mc = jax.lax.scan(body, x, (params["mambas"], cache["mambas"]))
        adapt = (x @ params["lora_a"].astype(x.dtype)) @ params["lora_b"].astype(x.dtype)
        x, ac = _dense_block_decode(shared["block"], x + adapt, cache["attn"], cfg)
        return x, {"mambas": mc, "attn": ac}
    if kind == "xlstm_super":
        def body(h, pc):
            p, c = pc
            y, c = mlstm_decode(p["core"], rms_norm(p["ln"], h, cfg.norm_eps), c, cfg)
            return h + y, c
        x, mc = jax.lax.scan(body, x, (params["mlstms"], cache["mlstms"]))
        y, sc = slstm_decode(
            params["slstm"]["core"],
            rms_norm(params["slstm"]["ln"], x, cfg.norm_eps),
            cache["slstm"], cfg)
        return x + y, {"mlstms": mc, "slstm": sc}
    raise ValueError(kind)


def decoder_decode(params, cfg: ModelConfig, cache, token=None, embedding=None,
                   unroll: bool = False):
    """One-token decode step -> (logits (B,1,V), new_cache)."""
    if embedding is None:
        x = embed(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    else:
        x = embedding.astype(jnp.dtype(cfg.dtype))
    shared = params.get("shared_attn")
    new_segs = []
    for (kind, n_iter, per), seg_params, seg_cache in zip(
            segments_for(cfg), params["segments"], cache):
        def body(h, pc, _kind=kind, _per=per):
            p, c = pc
            h, c = _decode_super(_kind, p, h, c, cfg, shared, _per)
            return h, c
        if unroll:
            outs = []
            for i in range(n_iter):
                pc_i = jax.tree.map(lambda a: a[i], (seg_params, seg_cache))
                x, c_i = body(x, pc_i)
                outs.append(c_i)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segs.append(new_cache)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, new_segs
