from .model import Model, build, count_params, decode_input_specs, input_specs, model_flops

__all__ = ["Model", "build", "count_params", "decode_input_specs", "input_specs", "model_flops"]
