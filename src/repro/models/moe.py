"""Mixture-of-Experts layer with static-shape sort-based dispatch.

Megablocks-style routing without custom kernels, XLA/pjit friendly:

  1. router logits -> top-k experts per token (+ softmax weights);
  2. the (tokens*k) assignments are sorted by expert id (static shape);
  3. each assignment's position *within its expert* comes from the sorted
     order; assignments beyond the per-expert capacity C are dropped
     (GShard-style accounting, capacity_factor configurable);
  4. tokens are gathered into an (E, C, d) buffer, two einsums apply the
     expert FFNs, and results scatter back weighted by router probs.

Sharding: the (E, C, d) buffer shards E over the "model" mesh axis (expert
parallelism) and C over "data"; the gather/scatter between token-sharded
and expert-sharded layouts lowers to all-to-alls under pjit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _he, dense, init_dense

__all__ = ["init_moe", "moe_apply", "moe_capacity"]


def moe_capacity(n_tokens: int, cfg) -> int:
    """Per-expert capacity with the configured slack factor."""
    k = cfg.experts_per_token
    c = int(cfg.capacity_factor * n_tokens * k / cfg.n_experts)
    return max(8, min(c, n_tokens))


def init_moe(key, cfg):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    kr, kg, ku, ko, ks = jax.random.split(key, 5)
    p = {
        "router": {"kernel": _he(kr, (d, E), d)},
        "wi_gate": {"kernel": _he(kg, (E, d, ff), d)},
        "wi_up": {"kernel": _he(ku, (E, d, ff), d)},
        "wo": {"kernel": _he(ko, (E, ff, d), ff)},
    }
    if cfg.shared_expert:
        from .layers import init_mlp

        p["shared"] = init_mlp(ks, d, cfg.d_ff)
    return p


def moe_apply(params, x, cfg):
    """x: (B, S, d) -> (B, S, d), plus aux load-balancing loss."""
    B, S, d = x.shape
    T = B * S
    k = cfg.experts_per_token
    E = cfg.n_experts
    C = moe_capacity(T, cfg)
    xt = x.reshape(T, d)

    logits = dense(params["router"], xt).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                 # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # ---- sort assignments by expert --------------------------------------
    flat_expert = expert_ids.reshape(-1)                            # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)                                # stable enough
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # position within expert = index - start-of-expert (via counts cumsum)
    counts = jnp.bincount(sorted_expert, length=E)                  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(T * k) - starts[sorted_expert]
    keep = pos_in_expert < C

    # ---- gather to (E, C, d) ----------------------------------------------
    slot = sorted_expert * C + jnp.where(keep, pos_in_expert, 0)
    buf = jnp.zeros((E * C, d), xt.dtype)
    buf = buf.at[jnp.where(keep, slot, E * C - 1)].add(
        jnp.where(keep[:, None], xt[sorted_token], 0.0)
    )
    buf = buf.reshape(E, C, d)

    # ---- expert FFNs (einsum over the expert dim) ---------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"]["kernel"].astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"]["kernel"].astype(buf.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, params["wo"]["kernel"].astype(buf.dtype))
    out = out.reshape(E * C, d)

    # ---- scatter back weighted ----------------------------------------------
    gathered = out[jnp.where(keep, slot, 0)] * jnp.where(keep, sorted_gate, 0.0)[:, None].astype(out.dtype)
    yt = jnp.zeros((T, d), x.dtype)
    yt = yt.at[sorted_token].add(gathered.astype(x.dtype))

    if cfg.shared_expert:
        from .layers import mlp

        yt = yt + mlp(params["shared"], xt)

    # load-balancing aux loss (Switch-style): E * sum(frac_tokens * frac_prob)
    frac_tokens = counts.astype(jnp.float32) / jnp.maximum(1, T * k)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return yt.reshape(B, S, d), aux
