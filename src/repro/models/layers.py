"""Shared neural layers: norms, MLPs, embeddings, RoPE/M-RoPE.

Pure-functional JAX: params are nested dicts of jnp arrays; every layer is
``init(key, cfg) -> params`` + ``apply(params, x, ...) -> y``.  Weight
layouts follow the (in_dim, ..., out_dim) convention that the sharding
rules in ``repro.distributed.sharding`` key off of (see leaf names there).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "layer_norm",
    "init_dense",
    "dense",
    "init_mlp",
    "mlp",
    "init_embedding",
    "embed",
    "unembed",
    "rope",
    "mrope",
    "rope_freqs",
]


def _he(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# -- norms -------------------------------------------------------------------

def rms_norm(params, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def init_layer_norm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


# -- dense -------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, bias: bool = False):
    p = {"kernel": _he(key, (d_in, d_out), d_in)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(params, x):
    y = x @ params["kernel"].astype(x.dtype)
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


# -- gated MLP (SwiGLU) --------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": {"kernel": _he(k1, (d_model, d_ff), d_model)},
        "wi_up": {"kernel": _he(k2, (d_model, d_ff), d_model)},
        "wo": {"kernel": _he(k3, (d_ff, d_model), d_ff)},
    }


def mlp(params, x):
    g = dense(params["wi_gate"], x)
    u = dense(params["wi_up"], x)
    return dense(params["wo"], jax.nn.silu(g) * u)


# -- embeddings ----------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int):
    return {"table": _he(key, (vocab, d_model), d_model)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Tied or separate logits projection: x @ table^T."""
    return x @ params["table"].T.astype(x.dtype)


# -- rotary position embedding ---------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def _rotate(x, sin, cos):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    return _rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)


def mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """Multimodal RoPE (qwen2-vl): head_dim halves split into (t, h, w)
    sections, each rotated with its own position stream.

    x: (..., seq, heads, head_dim); positions3: (3, ..., seq).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, "mrope sections must cover head_dim/2"
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # static
    # pick the position stream per frequency slot
    pos = jnp.take(positions3, sec_id, axis=0)  # (half, ..., seq) -> move axis
    pos = jnp.moveaxis(pos, 0, -1)  # (..., seq, half)
    ang = pos.astype(jnp.float32) * freqs
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    return _rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)
