"""Mamba2 (SSD) blocks + the zamba2 hybrid shared-attention wrapper.

Training/prefill uses the chunked state-space-duality form (quadratic only
within `chunk` and linear across chunks — the standard Mamba2 algorithm),
decode uses the O(1) recurrent update on a carried (H, P, N) state.  The
chunked einsums were written so the sequence dim can shard (long_500k).

zamba2: a Mamba2 backbone where every ``hybrid_attn_every``-th layer is
followed by a *shared* transformer block (one set of weights, applied at
each hybrid point) with a per-use LoRA adapter — the paper's memory trick.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import _he, dense, init_dense, init_rms_norm, rms_norm

__all__ = [
    "init_mamba",
    "mamba_apply",
    "mamba_decode",
    "init_mamba_cache",
    "MambaCache",
    "ssd_chunked",
]

_CONV_K = 4
_CHUNK = 256


class MambaCache(NamedTuple):
    state: jnp.ndarray      # (B, H, P, N) recurrent SSM state
    conv: jnp.ndarray       # (B, CONV_K-1, conv_channels) rolling window


def init_mamba(key, cfg):
    d = cfg.d_model
    d_in = cfg.d_inner
    H = cfg.n_ssm_heads
    N = cfg.ssm_state
    conv_ch = d_in + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # order: [z (d_in), x (d_in), B (N), C (N), dt (H)]
        "in_proj": {"kernel": _he(k1, (d, 2 * d_in + 2 * N + H), d)},
        "conv": {"kernel": _he(k2, (_CONV_K, conv_ch), _CONV_K)},
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm": init_rms_norm(d_in),
        "out_proj": {"kernel": _he(k3, (d_in, d), d_in)},
    }


def _segsum(a):
    """(..., q) log-decays -> (..., q, q) lower-triangular pairwise sums."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, a, B, C, init_state=None, chunk: int = _CHUNK):
    """State-space-duality scan.

    x: (b, l, h, p)   inputs (already dt-weighted)
    a: (b, l, h)      per-step log decay (<= 0)
    B: (b, l, n)      input projection (shared across heads, G=1)
    C: (b, l, n)      output projection
    returns y (b, l, h, p), final_state (b, h, p, n)
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, "sequence must divide the SSD chunk"
    c = l // chunk
    xr = x.reshape(b, c, chunk, h, p)
    ar = a.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)       # (b,h,c,q)
    Br = B.reshape(b, c, chunk, n)
    Cr = C.reshape(b, c, chunk, n)

    a_cum = jnp.cumsum(ar, axis=-1)                            # (b,h,c,q)
    # 1. intra-chunk (attention-like)
    L = jnp.exp(_segsum(ar))                                   # (b,h,c,q,q)
    Y_diag = jnp.einsum("bcqn,bcsn,bhcqs,bcshp->bcqhp", Cr, Br, L.astype(x.dtype), xr)
    # 2. per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)            # (b,h,c,q)
    states = jnp.einsum("bcqn,bhcq,bcqhp->bchpn", Br, decay_states.astype(x.dtype), xr)
    # 3. inter-chunk recurrence (one segsum over chunk decays)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), x.dtype)
    chunk_decay = a_cum[..., -1]                               # (b,h,c)
    padded = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(padded))                     # (b,h,c+1,c+1)
    states_all = jnp.concatenate([init_state[:, None], states], axis=1)
    # states_all: (b, c+1, h, p, n); combine with decay matrix rows
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk.astype(x.dtype), states_all)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]
    # 4. contribution of carried state to each position
    state_decay = jnp.exp(a_cum)                               # (b,h,c,q)
    Y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", Cr, prev_states, state_decay.astype(x.dtype))
    y = (Y_diag + Y_off).reshape(b, l, h, p)
    return y, final_state


def _split_proj(params, u, cfg):
    d_in = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    zxbcdt = dense(params["in_proj"], u)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : 2 * d_in + 2 * N]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * N :]
    return z, xBC, dt_raw, d_in, N, H


def mamba_apply(params, u, cfg):
    """Full-sequence Mamba2 mixer: u (B, L, d) -> (B, L, d)."""
    Bb, L, _ = u.shape
    z, xBC, dt_raw, d_in, N, H = _split_proj(params, u, cfg)
    # causal depthwise conv over (x, B, C)
    k = params["conv"]["kernel"].astype(xBC.dtype)             # (K, ch)
    pad = jnp.pad(xBC, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    conv = sum(pad[:, i : i + L] * k[i] for i in range(_CONV_K))
    conv = jax.nn.silu(conv)
    x = conv[..., :d_in].reshape(Bb, L, H, cfg.ssm_head_dim)
    Bm = conv[..., d_in : d_in + N]
    Cm = conv[..., d_in + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])   # (B,L,H)
    A = -jnp.exp(params["A_log"])                              # (H,) negative
    a = dt * A                                                 # log decay
    y, _ = ssd_chunked((x * dt[..., None].astype(x.dtype)), a, Bm, Cm)
    y = y + x * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bb, L, d_in)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(params["out_proj"], y)


def init_mamba_cache(batch: int, cfg, dtype=jnp.float32) -> MambaCache:
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * N
    return MambaCache(
        state=jnp.zeros((batch, H, P, N), dtype),
        conv=jnp.zeros((batch, _CONV_K - 1, conv_ch), dtype),
    )


def mamba_decode(params, u, cache: MambaCache, cfg) -> Tuple[jnp.ndarray, MambaCache]:
    """One-token recurrent step: u (B, 1, d)."""
    Bb = u.shape[0]
    z, xBC, dt_raw, d_in, N, H = _split_proj(params, u, cfg)
    xBC = xBC[:, 0]                                            # (B, ch)
    window = jnp.concatenate([cache.conv, xBC[:, None, :].astype(cache.conv.dtype)], axis=1)
    k = params["conv"]["kernel"].astype(window.dtype)
    conv = (window * k[None]).sum(axis=1)
    conv = jax.nn.silu(conv)
    x = conv[:, :d_in].reshape(Bb, H, cfg.ssm_head_dim)
    Bm = conv[:, d_in : d_in + N]
    Cm = conv[:, d_in + N :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                                    # (B,H)
    upd = (dt[..., None].astype(x.dtype) * x)[..., None] * Bm[:, None, None, :]
    state = cache.state * decay[..., None, None].astype(cache.state.dtype) + upd.astype(cache.state.dtype)
    y = jnp.einsum("bhpn,bn->bhp", state.astype(x.dtype), Cm)
    y = y + x * params["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bb, 1, d_in)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    # keep the activation dtype stable across the residual stream (the cache
    # is f32; without this cast decode carries would promote to f32)
    out = dense(params["out_proj"], y.astype(u.dtype))
    return out, MambaCache(state=state, conv=window[:, 1:])
