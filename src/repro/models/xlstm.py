"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, strictly recurrent) — Beck et al., arXiv:2405.04517.

mLSTM is linear attention with data-dependent exponential gating:

    C_t = f_t C_{t-1} + i_t (v_t k_t^T);   n_t = f_t n_{t-1} + i_t k_t
    y_t = (C_t q_t) / max(|n_t . q_t|, 1)

The parallel/chunked form reuses the SSD scan from ``repro.models.ssm``
(state = C augmented with the normalizer row by appending a constant-1
channel to v).  sLSTM has no parallel form — it is a ``lax.scan`` over
time by construction (noted in DESIGN.md; this is the architecture, not an
implementation shortcut).  xlstm-1.3b interleaves them 5:1.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import _he, dense, init_dense, init_rms_norm, rms_norm
from .ssm import ssd_chunked

__all__ = [
    "init_mlstm",
    "mlstm_apply",
    "mlstm_decode",
    "init_mlstm_cache",
    "init_slstm",
    "slstm_apply",
    "slstm_decode",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLstmCache(NamedTuple):
    C: jnp.ndarray    # (B, H, P+1, K) matrix memory (+normalizer row)
    m: jnp.ndarray    # (B, H) gate stabilizer (running max of log gates)


def init_mlstm(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    hd = cfg.head_dim_
    kq, kk, kv, kg, ko, kz = jax.random.split(key, 6)
    return {
        "wq": init_dense(kq, d, H * hd),
        "wk": init_dense(kk, d, H * hd),
        "wv": init_dense(kv, d, H * hd),
        "w_gates": init_dense(kg, d, 2 * H, bias=True),  # i, f per head
        "wz": init_dense(kz, d, H * hd),                 # output gate branch
        "norm": init_rms_norm(H * hd),
        "wo": init_dense(ko, H * hd, d),
    }


def _mlstm_qkv(params, x, cfg):
    B, L, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim_
    q = dense(params["wq"], x).reshape(B, L, H, hd)
    k = dense(params["wk"], x).reshape(B, L, H, hd) / jnp.sqrt(hd).astype(x.dtype)
    v = dense(params["wv"], x).reshape(B, L, H, hd)
    gates = dense(params["w_gates"], x).reshape(B, L, H, 2).astype(jnp.float32)
    log_i = -jax.nn.softplus(-gates[..., 0])       # log sigmoid(i)
    log_f = -jax.nn.softplus(-gates[..., 1])       # log sigmoid(f)
    return q, k, v, log_i, log_f


def mlstm_apply(params, x, cfg):
    """Full-sequence mLSTM via the SSD chunked scan (per-head decays)."""
    B, L, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim_
    q, k, v, log_i, log_f = _mlstm_qkv(params, x, cfg)
    # augment v with ones so the normalizer n rides along as channel hd
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    # ssd expects shared B/C over heads; mLSTM k/q are per-head, so run the
    # scan per head via vmap over the head axis.
    def per_head(xh, ah, Bh, Ch):
        y, _ = ssd_chunked(xh[:, :, None], ah[..., None], Bh, Ch)
        return y[:, :, 0]

    # input weighting: i_t enters multiplicatively (like dt in SSD)
    xs = v_aug * jnp.exp(log_i)[..., None].astype(v.dtype)
    y = jax.vmap(per_head, in_axes=(2, 2, 2, 2), out_axes=2)(
        xs, log_f, k, q
    )  # (B, L, H, hd+1)
    num, den = y[..., :-1], y[..., -1:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    z = dense(params["wz"], x)
    y = y.reshape(B, L, H * hd) * jax.nn.silu(z)
    y = rms_norm(params["norm"], y, cfg.norm_eps)
    return dense(params["wo"], y)


def init_mlstm_cache(batch: int, cfg, dtype=jnp.float32) -> MLstmCache:
    H, hd = cfg.n_heads, cfg.head_dim_
    return MLstmCache(
        C=jnp.zeros((batch, H, hd + 1, hd), dtype),
        m=jnp.full((batch, H), -1e9, dtype),
    )


def mlstm_decode(params, x, cache: MLstmCache, cfg) -> Tuple[jnp.ndarray, MLstmCache]:
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim_
    q, k, v, log_i, log_f = _mlstm_qkv(params, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    log_i, log_f = log_i[:, 0], log_f[:, 0]
    # stabilized exponential gating (xLSTM eq. 15-18)
    m_new = jnp.maximum(log_f + cache.m, log_i)
    f_eff = jnp.exp(log_f + cache.m - m_new)
    i_eff = jnp.exp(log_i - m_new)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    C = cache.C * f_eff[..., None, None].astype(cache.C.dtype) + (
        i_eff[..., None, None].astype(v.dtype) * v_aug[..., None] * k[..., None, :]
    ).astype(cache.C.dtype)
    y = jnp.einsum("bhpk,bhk->bhp", C.astype(q.dtype), q)
    num, den = y[..., :-1], y[..., -1]
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    z = dense(params["wz"], x)[:, 0]
    y = y.reshape(B, H * hd) * jax.nn.silu(z)
    y = rms_norm(params["norm"], y, cfg.norm_eps)
    out = dense(params["wo"], y)[:, None, :]
    return out, MLstmCache(C=C, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLstmCache(NamedTuple):
    c: jnp.ndarray    # (B, d)
    n: jnp.ndarray    # (B, d)
    h: jnp.ndarray    # (B, d)
    m: jnp.ndarray    # (B, d) stabilizer


def init_slstm(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    hb = d // H
    kx, kr, ko = jax.random.split(key, 3)
    return {
        # x -> (z, i, f, o) pre-activations
        "wx": init_dense(kx, d, 4 * d, bias=True),
        # block-diagonal recurrent weights per head: (H, hb, 4*hb)
        "r": _he(kr, (H, hb, 4 * hb), hb),
        "norm": init_rms_norm(d),
        "wo": init_dense(ko, d, d),
    }


def _slstm_step(params, cfg, carry, xw):
    c, n, h, m = carry
    B = c.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    hb = d // H
    hr = h.reshape(B, H, hb)
    rec = jnp.einsum("bhi,hio->bho", hr, params["r"].astype(h.dtype))  # (B,H,4hb)
    # re-lay (B,H,4,hb) -> z|i|f|o blocks of (B,d) to match wx's output
    rec = rec.reshape(B, H, 4, hb).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    zifo = xw + rec.astype(xw.dtype)
    z, i_raw, f_raw, o_raw = jnp.split(zifo.astype(jnp.float32), 4, axis=-1)
    log_i = -jax.nn.softplus(-i_raw)
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + m, log_i)
    i_eff = jnp.exp(log_i - m_new)
    f_eff = jnp.exp(log_f + m - m_new)
    c_new = f_eff * c + i_eff * jnp.tanh(z)
    n_new = f_eff * n + i_eff
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new.astype(h.dtype), m_new), h_new


def slstm_apply(params, x, cfg):
    """Strictly recurrent sLSTM over the sequence (lax.scan)."""
    B, L, d = x.shape
    xw = dense(params["wx"], x).astype(jnp.float32)            # (B, L, 4d)
    init = (
        jnp.zeros((B, d), jnp.float32),
        jnp.zeros((B, d), jnp.float32),
        jnp.zeros((B, d), x.dtype),
        jnp.full((B, d), -1e9, jnp.float32),
    )
    def step(carry, xt):
        return _slstm_step(params, cfg, carry, xt)

    _, hs = jax.lax.scan(step, init, xw.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)                  # (B, L, d)
    y = rms_norm(params["norm"], y, cfg.norm_eps)
    return dense(params["wo"], y)


def init_slstm_cache(batch: int, cfg, dtype=jnp.float32) -> SLstmCache:
    d = cfg.d_model
    return SLstmCache(
        c=jnp.zeros((batch, d), jnp.float32),
        n=jnp.zeros((batch, d), jnp.float32),
        h=jnp.zeros((batch, d), dtype),
        m=jnp.full((batch, d), -1e9, jnp.float32),
    )


def slstm_decode(params, x, cache: SLstmCache, cfg) -> Tuple[jnp.ndarray, SLstmCache]:
    xw = dense(params["wx"], x)[:, 0].astype(jnp.float32)
    carry = (cache.c, cache.n, cache.h, cache.m)
    (c, n, h, m), h_out = _slstm_step(params, cfg, carry, xw)
    y = rms_norm(params["norm"], h_out.astype(x.dtype), cfg.norm_eps)
    out = dense(params["wo"], y)[:, None, :]
    return out, SLstmCache(c=c, n=n, h=h.astype(cache.h.dtype), m=m)
