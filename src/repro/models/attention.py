"""GQA attention with RoPE/M-RoPE, sliding windows, and KV caches.

Three entry points:
  * ``attend``       — full-sequence (training / prefill), causal or not,
                       optional sliding window;
  * ``decode_attend`` — one-step decode against a (batch, S, kv, hd) cache;
  * ``init_cache`` / cache update helpers.

Shapes: q (B, S, H, D); k/v (B, S, KV, D) with H % KV == 0 (GQA groups).
Softmax in f32.  Sequence-sharded decode (flash-decoding-style partial
softmax) lives in ``repro.distributed.sp`` and is a hillclimb variant.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import dense, init_dense, mrope, rope

__all__ = [
    "init_attention",
    "attention",
    "decode_attention",
    "init_cache",
    "KVCache",
]


class KVCache(NamedTuple):
    k: jnp.ndarray     # (B, S_max, KV, D)
    v: jnp.ndarray     # (B, S_max, KV, D)
    length: jnp.ndarray  # scalar int32: tokens already cached


def init_attention(key, cfg):
    hd = cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, cfg.d_model, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": init_dense(kk, cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": init_dense(kv, cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": init_dense(ko, cfg.n_heads * hd, cfg.d_model),
    }


def _project_qkv(params, x, cfg):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = dense(params["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(params["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(params["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def _apply_rope(q, k, positions, cfg):
    if cfg.mrope_sections is not None:
        # positions: (3, B, S)
        q = mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k


def _sdpa(q, k, v, mask, cfg):
    """q (B,S,H,D), k/v (B,T,KV,D) -> (B,S,H,D); GQA via head grouping."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, D)


_BLOCK_Q = 1024
_BLOCK_KV = 1024
_BLOCK_THRESHOLD = 2048  # sequences beyond this use the blocked path


def _sdpa_blocked(q, k, v, cfg, causal: bool, window: int = 0):
    """Flash-style blocked attention: online softmax over KV chunks inside a
    scan over Q chunks — never materializes the (S, T) score matrix.

    §Perf hillclimb #1: the dense reference path materializes
    B*H*S*T f32 scores (200+ GB/device at 32k prefill) and, when head_dim is
    model-sharded, all-reduces them.  The blocked path caps live scores at
    (B, H, BLOCK_Q, BLOCK_KV) and composes with the head/sequence sharding
    constraint (hillclimb #2, ``_constrain_heads_or_seq``).
    """
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(_BLOCK_Q, S)
    bkv = min(_BLOCK_KV, T)
    nq, nkv = -(-S // bq), -(-T // bkv)
    pad_q, pad_kv = nq * bq - S, nkv * bkv - T
    qg = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))).reshape(
        B, nq, bq, KV, G, D).transpose(1, 0, 2, 3, 4, 5)  # (nq,B,bq,KV,G,D)
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0))).reshape(
        B, nkv, bkv, KV, D)
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0))).reshape(
        B, nkv, bkv, KV, D)
    q_off = T - S  # causal alignment for prefill-style q suffixes
    neg = jnp.float32(jnp.finfo(jnp.float32).min)

    def per_q_chunk(qi, qblk):
        qpos = qi * bq + jnp.arange(bq) + q_off            # (bq,)

        def inner(carry, inputs):
            kj, kblk, vblk = inputs
            kpos = kj * bkv + jnp.arange(bkv)              # (bkv,)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qblk, kblk).astype(jnp.float32)
            s = s / jnp.sqrt(D).astype(jnp.float32)
            m_ok = kpos[None, :] < T                       # kv padding
            if causal:
                m_ok &= kpos[None, :] <= qpos[:, None]
            if window:
                m_ok &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(m_ok[None, None, None], s, neg)
            acc, m, l = carry
            m_new = jnp.maximum(m, s.max(axis=-1))
            scale = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * scale + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(qblk.dtype), vblk)
            acc = acc * scale[..., None].astype(acc.dtype) + pv
            return (acc, m_new, l), None

        init = (
            jnp.zeros((B, KV, G, bq, D), qblk.dtype),
            jnp.full((B, KV, G, bq), neg),
            jnp.zeros((B, KV, G, bq), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(
            inner, init,
            (jnp.arange(nkv), kp.transpose(1, 0, 2, 3, 4),
             vp.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, bq, H, D)

    outs = jax.lax.map(lambda args: per_q_chunk(*args),
                       (jnp.arange(nq), qg))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, D)
    return out[:, :S]


def _constrain_heads_or_seq(x, cfg, seq_axis: int = 1, head_axis: int = 2):
    """§Perf hillclimb #2: attention activation sharding constraint.

    If the head count divides the model axis, shard heads; otherwise shard
    the *query sequence* on the model axis (context-parallel attention with
    gathered KV).  The fallback of sharding head_dim (what the propagation
    picks by default from the weight layouts) makes XLA all-reduce the full
    score tensor — ~5e11 B/layer at 32k prefill for minitron-4b, measured in
    EXPERIMENTS.md §Perf.  No-op off-mesh (CPU tests).
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or "model" not in getattr(mesh, "shape", {}):
        return x
    tp = mesh.shape["model"]
    spec = [None] * x.ndim
    if x.shape[head_axis] % tp == 0:
        spec[head_axis] = "model"
    elif x.shape[seq_axis] % tp == 0:
        spec[seq_axis] = "model"
    else:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*spec))


def _causal_mask(S: int, T: int, window: int = 0):
    """(1,1,1,S,T) boolean mask; T >= S, aligned at the end (prefill)."""
    qi = jnp.arange(S)[:, None] + (T - S)
    ki = jnp.arange(T)[None, :]
    m = ki <= qi
    if window:
        m &= ki > qi - window
    return m[None, None, None]


def attention(
    params,
    x,
    positions,
    cfg,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _apply_rope(q, k, positions, cfg)
    if S > _BLOCK_THRESHOLD:
        q = _constrain_heads_or_seq(q, cfg)
        out = _sdpa_blocked(q, k, v, cfg, causal=causal, window=window)
        out = _constrain_heads_or_seq(out, cfg)
    else:
        mask = _causal_mask(S, S, window) if causal else None
        out = _sdpa(q, k, v, mask, cfg)
    return dense(params["wo"], out.reshape(B, S, -1))


def init_cache(batch: int, max_len: int, cfg, dtype=jnp.bfloat16) -> KVCache:
    """Zero-filled :class:`KVCache` sized for ``batch`` sequences of up to
    ``max_len`` tokens under ``cfg``'s KV-head/head-dim layout."""
    hd = cfg.head_dim_
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def decode_attention(
    params,
    x,
    cache: KVCache,
    cfg,
    window: int = 0,
):
    """One-token decode: x (B, 1, d); returns (y, new_cache).

    The cache holds ``length`` valid tokens; the new token is written at
    ``length`` (or at ``length % window`` ring position for windowed
    layers, which keeps the cache O(window) for gemma3-style local
    attention at 500k contexts).
    """
    B, S, _ = x.shape
    assert S == 1, "decode_attention is one token at a time"
    pos = cache.length[None, None]  # (1,1) broadcasting as positions
    q, k, v = _project_qkv(params, x, cfg)
    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(pos, (3, B, 1) if pos.ndim == 2 else pos.shape)
        q, k = _apply_rope(q, k, pos3, cfg)
    else:
        q, k = _apply_rope(q, k, jnp.broadcast_to(pos, (B, 1)), cfg)
    T = cache.k.shape[1]
    slot = jnp.where(window > 0, cache.length % jnp.int32(max(1, window)),
                     cache.length) if window else cache.length
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    # valid-position mask: positions < length+1 (ring buffers are always full
    # once length >= window, and slots beyond are masked before that)
    ki = jnp.arange(T)[None, None, None, None, :]
    valid = ki <= jnp.minimum(cache.length, T - 1)
    out = _sdpa(q, ck, cv, valid, cfg)
    y = dense(params["wo"], out.reshape(B, 1, -1))
    return y, KVCache(k=ck, v=cv, length=cache.length + 1)
