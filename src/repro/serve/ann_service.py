"""AnnService — request micro-batching over any ``repro.api`` index.

The serving deployment the paper motivates: a RAM-resident ANN index with
losslessly-compressed ids answers nearest-neighbor requests from many
clients.  The service holds any :class:`repro.api.Index` — factory-built
IVF, NSG/HNSW graph or flat — through the one protocol (raw
``IVFIndex``/``GraphIndex`` instances are auto-wrapped), so graph and IVF
requests flow through the same code path.  Per-structure search knobs
(``nprobe`` for IVF, ``ef`` for graphs, ``engine`` for both — each runs
a batched scan engine) ride in as keyword options; ``cache_mb``
overrides the index's decoded-list cache budget.

Individual requests are small (often one query); the batched IVF engine
(repro.ann.scan) only pays off when whole query blocks hit the kernels
together.  This service closes that gap with a max-batch/max-wait
micro-batching policy:

* ``submit(queries)`` enqueues a request and returns a :class:`Ticket`.
  A flush is triggered when the pending queue reaches ``max_batch``
  queries, or when the oldest pending request has waited ``max_wait_s``.
* ``flush()`` concatenates all pending requests into one query block,
  runs a single batched search, and splits ids/distances back per ticket
  (each ticket also records its wait time, batch id and batch size).
* ``tick()`` lets a driver loop enforce the max-wait deadline without new
  arrivals (the clock is injectable, so tests are deterministic).

Batching never changes results — the scan layer's batching contract
guarantees the answer for each query is independent of what it was
batched with.

The service also keeps a **memory ledger** (:meth:`memory_ledger`):
compressed id bytes vs the uncompressed/compact layouts, code/vector
payload, centroids, and the decoded-list LRU cache — the numbers a
capacity planner needs for "how many replicas fit in this RAM".
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

__all__ = ["AnnService", "AddTicket", "BatchPolicy", "Ticket"]


@dataclasses.dataclass
class BatchPolicy:
    """Micro-batching knobs: flush at ``max_batch`` queued queries or when
    the oldest request has waited ``max_wait_s`` seconds."""

    max_batch: int = 64
    max_wait_s: float = 0.002


@dataclasses.dataclass
class Ticket:
    """One request's handle; filled in when its batch is flushed."""

    request_id: int
    n_queries: int
    enqueued_at: float
    done: bool = False
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None
    batch_id: int = -1
    batch_size: int = 0            # total queries in the flushed batch
    wait_s: float = 0.0            # enqueue -> flush start
    search_s: float = 0.0          # batch search wall time (shared)
    latency_s: float = 0.0         # submit -> results ready (wait + search)
    keys: Optional[np.ndarray] = None  # stable-merge keys (with_keys searches)


@dataclasses.dataclass
class AddTicket:
    """One ingest request's handle; filled in when its batch is applied."""

    request_id: int
    n_rows: int
    enqueued_at: float
    done: bool = False
    ids: Optional[np.ndarray] = None   # global ids assigned to the rows
    batch_id: int = -1
    batch_size: int = 0                # total rows in the applied batch
    wait_s: float = 0.0
    apply_s: float = 0.0               # batch apply wall time (shared)


class AnnService:
    """Micro-batching front-end over any ``repro.api.Index``.

    ``**search_opts`` are forwarded to every ``index.search`` call
    (IVF: ``nprobe``/``engine``/``query_block``/``select``; graph:
    ``ef``/``engine``/``query_block``/``select``), so one service class
    serves every index type.  ``clock`` is injectable
    (defaults to ``time.perf_counter``) so the max-wait policy is
    testable without sleeping.
    """

    def __init__(self, index, topk: int = 10,
                 policy: Optional[BatchPolicy] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 cache_mb: Optional[float] = None, **search_opts):
        from ..api.indexes import as_api_index

        self.index = as_api_index(index)
        self.topk = topk
        self.policy = policy or BatchPolicy()
        self.search_opts = search_opts
        self.clock = clock
        if cache_mb is not None:
            inner = getattr(self.index, "ivf", None) or getattr(
                self.index, "graph", None)
            if inner is None:
                raise ValueError(
                    f"index {self.index.spec!r} has no decoded-list cache "
                    "to budget")
            inner.decoded_cache.set_budget(int(cache_mb * (1 << 20)))
        self._pending: List[Ticket] = []
        self._pending_q: List[np.ndarray] = []
        self._pending_add: List[AddTicket] = []
        self._pending_add_x: List[np.ndarray] = []
        self._next_id = 0
        self.reset_stats()

    def reset_stats(self) -> None:
        """Zero the service counters (e.g. after a jit warm-up call)."""
        self.requests = 0
        self.queries = 0
        self.batches = 0
        self.adds = 0
        self.add_rows = 0
        self.add_batches = 0
        self.add_s = 0.0
        self.ndis = 0
        self.decodes = 0
        self.search_s = 0.0
        self.resolve_s = 0.0
        self.host_block_bytes = 0
        self.device_selects = 0
        self.last_stats = None         # SearchStats of the most recent flush
        # bounded: long-lived replicas must not grow per-request state
        self._batch_sizes: "deque[int]" = deque(maxlen=4096)
        self._waits: "deque[float]" = deque(maxlen=4096)
        self._lats: "deque[float]" = deque(maxlen=4096)

    # -- request path --------------------------------------------------------
    def submit(self, queries: np.ndarray) -> Ticket:
        """Enqueue one request (``(nq, d)`` or ``(d,)``); may trigger a flush."""
        queries = np.asarray(queries)
        if queries.ndim == 1:
            queries = queries[None]
        t = Ticket(request_id=self._next_id, n_queries=queries.shape[0],
                   enqueued_at=self.clock())
        self._next_id += 1
        self._pending.append(t)
        self._pending_q.append(queries)
        self.requests += 1
        self.queries += queries.shape[0]
        if self._pending_total() >= self.policy.max_batch:
            self.flush()
        else:
            self.tick()
        return t

    # -- ingest path ---------------------------------------------------------
    def submit_add(self, x: np.ndarray) -> AddTicket:
        """Enqueue rows for ingest (``(m, d)`` or ``(d,)``).

        Ingest micro-batches under the same policy as queries: appended
        rows are sealed into ONE epoch per flush (one entropy-coding pass
        per batch, not per request).  Any query flush applies pending adds
        first, so a submit -> search sequence always sees its own rows.
        """
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None]
        t = AddTicket(request_id=self._next_id, n_rows=x.shape[0],
                      enqueued_at=self.clock())
        self._next_id += 1
        self._pending_add.append(t)
        self._pending_add_x.append(x)
        self.adds += 1
        self.add_rows += x.shape[0]
        if self.pending_adds() >= self.policy.max_batch:
            self.flush_adds()
        else:
            self.tick()
        return t

    def flush_adds(self) -> List[AddTicket]:
        """Apply every pending add as one epoch; complete the tickets."""
        if not self._pending_add:
            return []
        tickets, self._pending_add = self._pending_add, []
        xs, self._pending_add_x = self._pending_add_x, []
        now = self.clock()
        x = np.concatenate(xs, axis=0)
        base = int(self.index.n)
        t0 = time.perf_counter()
        self.index.add(x)
        apply_s = time.perf_counter() - t0
        self.add_batches += 1
        self.add_s += apply_s
        row = 0
        for t in tickets:
            t.ids = np.arange(base + row, base + row + t.n_rows, dtype=np.int64)
            row += t.n_rows
            t.done = True
            t.batch_id = self.add_batches - 1
            t.batch_size = x.shape[0]
            t.wait_s = max(0.0, now - t.enqueued_at)
            t.apply_s = apply_s
        return tickets

    def add(self, x: np.ndarray) -> AddTicket:
        """Synchronous ingest convenience: submit + immediate apply."""
        t = self.submit_add(x)
        if not t.done:
            self.flush_adds()
        return t

    def pending_adds(self) -> int:
        """Rows currently queued for ingest (not yet applied)."""
        return sum(t.n_rows for t in self._pending_add)

    def tick(self) -> bool:
        """Flush if the oldest pending request exceeded the wait budget."""
        fired = False
        if self._pending_add and (self.clock() - self._pending_add[0].enqueued_at
                                  >= self.policy.max_wait_s):
            self.flush_adds()
            fired = True
        if not self._pending:
            return fired
        if self.clock() - self._pending[0].enqueued_at >= self.policy.max_wait_s:
            self.flush()
            return True
        return fired

    def flush(self) -> List[Ticket]:
        """Run one batched search over everything pending; complete tickets."""
        # read-your-writes: rows submitted before these queries must be live
        self.flush_adds()
        if not self._pending:
            return []
        tickets, self._pending = self._pending, []
        qs, self._pending_q = self._pending_q, []
        now = self.clock()
        batch = np.concatenate(qs, axis=0)
        dists, ids, st = self.index.search(batch, k=self.topk,
                                           **self.search_opts)
        done_at = self.clock()
        self.last_stats = st
        keys = getattr(st, "merge_keys", None)
        self.batches += 1
        self.ndis += st.ndis
        self.decodes += st.decodes
        self.search_s += st.wall_s
        self.resolve_s += st.id_resolve_s
        self.host_block_bytes += getattr(st, "host_block_bytes", 0)
        self.device_selects += getattr(st, "device_select", 0)
        self._batch_sizes.append(batch.shape[0])
        row = 0
        for t in tickets:
            t.ids = ids[row: row + t.n_queries]
            t.dists = dists[row: row + t.n_queries]
            if keys is not None:
                t.keys = keys[row: row + t.n_queries]
            row += t.n_queries
            t.done = True
            t.batch_id = self.batches - 1
            t.batch_size = batch.shape[0]
            t.wait_s = max(0.0, now - t.enqueued_at)
            t.search_s = st.wall_s
            t.latency_s = max(0.0, done_at - t.enqueued_at)
            self._waits.append(t.wait_s)
            self._lats.append(t.latency_s)
        return tickets

    def search(self, queries: np.ndarray):
        """Synchronous convenience: submit + immediate flush."""
        t = self.submit(queries)
        if not t.done:
            self.flush()
        return t.ids, t.dists

    def pending(self) -> int:
        """Queries currently queued for search (not yet flushed)."""
        return self._pending_total()

    def _pending_total(self) -> int:
        return sum(t.n_queries for t in self._pending)

    # -- accounting ----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Service counters and SLO accounting.

        Keys — counters are lifetime totals (since ``reset_stats``);
        distributions cover the last 4096 samples (bounded window):

        * ``requests`` / ``queries`` / ``batches`` — totals.
        * ``mean_batch`` / ``max_batch`` — flushed-batch size distribution.
        * ``mean_wait_s`` / ``p99_wait_s`` — enqueue -> flush-start wait
          (the micro-batching cost in isolation).
        * ``p50_latency_s`` / ``p95_latency_s`` / ``mean_latency_s`` —
          per-ticket submit -> results-ready wall time (wait + batched
          search), the per-request SLO numbers the sharded router reports.
        * ``search_s`` / ``resolve_s`` — cumulative index search wall and
          late-id-resolution time.
        * ``ndis`` / ``decodes`` — distance evaluations and id-list decode
          events (LRU misses).
        * ``host_block_bytes`` / ``device_selects`` — device-select
          ledger: bytes of device-computed distance data pulled to the
          host, and query blocks / graph steps whose top-k cut ran on
          device (``repro.kernels.seg_topk``).
        """
        bs = np.asarray(self._batch_sizes, np.float64)
        ws = np.asarray(self._waits, np.float64)
        ls = np.asarray(self._lats, np.float64)
        return {
            "requests": self.requests,
            "queries": self.queries,
            "batches": self.batches,
            "adds": self.adds,
            "add_rows": self.add_rows,
            "add_batches": self.add_batches,
            "add_s": self.add_s,
            "mean_batch": float(bs.mean()) if bs.size else 0.0,
            "max_batch": float(bs.max()) if bs.size else 0.0,
            "mean_wait_s": float(ws.mean()) if ws.size else 0.0,
            "p99_wait_s": float(np.quantile(ws, 0.99)) if ws.size else 0.0,
            "mean_latency_s": float(ls.mean()) if ls.size else 0.0,
            "p50_latency_s": float(np.quantile(ls, 0.50)) if ls.size else 0.0,
            "p95_latency_s": float(np.quantile(ls, 0.95)) if ls.size else 0.0,
            "search_s": self.search_s,
            "resolve_s": self.resolve_s,
            "ndis": self.ndis,
            "decodes": self.decodes,
            "host_block_bytes": self.host_block_bytes,
            "device_selects": self.device_selects,
        }

    def memory_ledger(self) -> Dict[str, float]:
        """Bytes by component, plus the uncompressed/compact baselines
        (delegated to the index — uniform across index types)."""
        return self.index.memory_ledger()
