"""Serving surface: prefill/decode step builders and cache utilities.

The implementations live next to their training counterparts
(repro.train.step) and the model cache constructors; this package is the
stable import point a serving deployment uses.
"""

from ..models.attention import KVCache, init_cache
from ..train.step import make_prefill_step, make_serve_step

__all__ = ["KVCache", "init_cache", "make_prefill_step", "make_serve_step"]
