"""Serving surface: prefill/decode step builders, cache utilities, and the
ANN micro-batching service.

The LM implementations live next to their training counterparts
(repro.train.step) and the model cache constructors; the ANN service wraps
the batched compressed-IVF scan (repro.ann.scan).  This package is the
stable import point a serving deployment uses.
"""

from ..models.attention import KVCache, init_cache
from ..train.step import make_prefill_step, make_serve_step
from .ann_service import AddTicket, AnnService, BatchPolicy, Ticket

__all__ = ["KVCache", "init_cache", "make_prefill_step", "make_serve_step",
           "AnnService", "AddTicket", "BatchPolicy", "Ticket"]
