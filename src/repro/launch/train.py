"""Training driver: any --arch at any scale, fault-tolerant by default.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised end-to-end (CPU-sized via --reduced, production mesh via
--mesh pod/multipod on the dry-run device fleet):
  * jit'd train_step with the repo sharding rules,
  * AdamW + cosine schedule, grad clipping,
  * optional int8 error-feedback gradient compression (--compress-grads),
  * checkpoint/restart: atomic saves every --ckpt-every, auto-resume from
    LATEST (kill the process mid-run and re-launch to test),
  * straggler/heartbeat hook: per-step wall-time watchdog that logs steps
    exceeding --deadline x median (the single-process analogue of
    skip-on-straggler at fleet scale).
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
from repro.configs import get_config, reduced
from repro.data.pipeline import TokenPipeline
from repro.train.optim import AdamWConfig, init_opt
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "never"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--deadline", type=float, default=3.0,
                    help="straggler threshold (x median step time)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--stop-after", type=int, default=0,
                    help="simulate a crash: exit after this many steps")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    model, train_step = make_train_step(cfg, opt_cfg,
                                        compress_grads=args.compress_grads)
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt(params)
    pipe = TokenPipeline(vocab=cfg.vocab_size, batch=args.batch,
                         seq_len=args.seq, seed=0)

    start = 0
    if args.ckpt_dir and args.resume == "auto":
        step0 = latest_step(args.ckpt_dir)
        if step0 is not None:
            (params, opt_state), manifest = restore_checkpoint(
                args.ckpt_dir, (params, opt_state))
            pipe.restore(manifest["extra"]["pipeline"])
            start = manifest["step"]
            print(f"[train] resumed from step {start}")

    times = []
    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        metrics = jax.tree.map(float, metrics)
        dt = time.perf_counter() - t0
        times.append(dt)
        losses.append(metrics["loss"])
        med = float(np.median(times[-50:]))
        if len(times) > 5 and dt > args.deadline * med:
            print(f"[straggler] step {step} took {dt:.3f}s (median {med:.3f}s)")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"ce {metrics['ce']:.4f} gnorm {metrics['grad_norm']:.3f} "
                  f"lr {metrics['lr']:.2e} {dt*1e3:.0f}ms", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            pipe.step = step + 1
            save_checkpoint(Path(args.ckpt_dir), step + 1, (params, opt_state),
                            extra={"pipeline": pipe.state()})
        if args.stop_after and step + 1 >= args.stop_after:
            print(f"[train] simulated crash after step {step + 1}")
            return losses
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({np.mean(times[1:])*1e3:.0f} ms/step)")
    return losses


if __name__ == "__main__":
    main()
