"""Production mesh construction (dry-run contract, DESIGN.md §6).

``make_production_mesh`` is a FUNCTION (not module state) so importing this
module never touches jax device initialization.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else in the repo sees the real (single) device.

``make_mesh_compat`` / ``use_mesh`` paper over the jax API drift around
meshes: ``axis_types=`` and ``jax.set_mesh`` only exist on newer jax;
on older versions Auto axes are the default and the ``Mesh`` object itself
is the context manager.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_compat", "use_mesh",
           "POD_SHAPE", "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)                 # 256 chips (one v5e pod slice)
MULTIPOD_SHAPE = (2, 16, 16)         # 2 pods = 512 chips


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types on any supported jax."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the current mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # old jax: Mesh is itself the context manager


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)
