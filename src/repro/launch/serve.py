"""Serving driver: batched LM decode + compressed retrieval side-car.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 8 --prompt-len 32 --gen 32

Runs prefill (full-sequence forward) then jit'd one-token decode steps
against the KV cache — the same ``serve_step`` the dry-run lowers for the
decode_32k / long_500k shapes — and reports tokens/s.  With --retrieval it
also mounts a RetrievalIndex and interleaves a kNN lookup per generated
token (the paper's feature in the serving loop).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build
from repro.train.step import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--retrieval", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model, serve_step = make_serve_step(cfg)
    jit_decode = jax.jit(serve_step, donate_argnums=(1,))

    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen
    cache_kw = {"mem_len": args.prompt_len} if cfg.encoder_decoder else {}
    cache = model.init_cache(args.batch, max_len, dtype=jnp.float32, **cache_kw)

    rng = np.random.default_rng(0)
    if cfg.encoder_decoder:
        from repro.models.encdec import encdec_prefill_memory

        frames = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)), jnp.float32)
        cache = encdec_prefill_memory(params, cfg, frames, cache)
        tok = jnp.zeros((args.batch, 1), jnp.int32)
    elif cfg.frontend == "vision":
        tok = None
    else:
        # prefill by feeding prompt tokens one at a time (decode path); a
        # production server uses the prefill_step — kept simple here
        prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
        for i in range(args.prompt_len):
            inputs = {"token": jnp.asarray(prompt[:, i:i + 1], jnp.int32)}
            tok, cache = jit_decode(params, cache, inputs)
        tok = tok[:, None].astype(jnp.int32)

    ri = None
    if args.retrieval:
        from repro.data.synthetic import make_dataset
        from repro.retrieval.index import RetrievalIndex

        base, _ = make_dataset("deep-like", 20_000, 10)
        ri = RetrievalIndex(nlist=64, id_codec="roc").build(base)
        print(f"[serve] retrieval side-car: "
              f"{ri.stats()['bits_per_id']:.2f} bits/id")

    steps = 0
    t0 = time.perf_counter()
    generated = []
    for _ in range(args.gen):
        if cfg.frontend == "vision":
            inputs = {"embedding": jnp.asarray(
                rng.standard_normal((args.batch, 1, cfg.d_model)), jnp.float32)}
        else:
            inputs = {"token": tok}
        nxt, cache = jit_decode(params, cache, inputs)
        tok = nxt[:, None].astype(jnp.int32)
        generated.append(np.asarray(nxt))
        steps += 1
        if ri is not None and steps % 8 == 0:
            q = rng.standard_normal((args.batch, 96)).astype(np.float32)
            ri.search(q, nprobe=4, topk=5)
    wall = time.perf_counter() - t0
    toks = steps * args.batch
    print(f"[serve] {toks} tokens in {wall:.2f}s -> {toks/wall:,.0f} tok/s "
          f"(batch {args.batch})")
    return np.stack(generated)


if __name__ == "__main__":
    main()
