import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this produces (and persists to experiments/dryrun/*.json):
  * compiled.memory_analysis()  — per-device bytes (proves the cell fits),
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes accessed,
  * collective operand bytes parsed from the post-SPMD HLO text, by op kind,
  * lowering + compile wall time.

The single-pod (16,16) mesh feeds the roofline table; the (2,16,16) mesh
proves the "pod" axis shards.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""

import argparse
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, shape_applicable, ARCH_IDS
from repro.launch.mesh import use_mesh
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models import build, decode_input_specs, input_specs, model_flops
from repro.train.optim import init_opt
from repro.train.step import make_prefill_step, make_serve_step, make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}
_HLO_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start)?\("
)
_TUPLE_RE = re.compile(
    r"=\s+\(((?:[a-z0-9]+\[[0-9,]*\][^,)]*,?\s*)+)\)\s*("
    + "|".join(_COLLECTIVES) + r")(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op, by kind."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        hit = None
        for kind in _COLLECTIVES:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                hit = kind
                break
        if hit is None:
            continue
        # take the result shape(s) on the lhs of '='
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
        total = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[hit] += total
        counts[hit] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _mem_stats(compiled) -> dict:
    m = compiled.memory_analysis()
    return {
        "argument_bytes": int(m.argument_size_in_bytes),
        "output_bytes": int(m.output_size_in_bytes),
        "temp_bytes": int(m.temp_size_in_bytes),
        "alias_bytes": int(m.alias_size_in_bytes),
        "code_bytes": int(m.generated_code_size_in_bytes),
    }


def _cost_analysis(compiled) -> dict:
    c = compiled.cost_analysis() or {}
    if isinstance(c, list):  # old jax returns one dict per computation
        c = c[0] if c else {}
    return c


def _cost_stats(compiled) -> dict:
    c = _cost_analysis(compiled)
    return {
        "flops": float(c.get("flops", -1.0)),
        "bytes_accessed": float(c.get("bytes accessed", -1.0)),
        "transcendentals": float(c.get("transcendentals", 0.0)),
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool, step_kind: str | None = None):
    """Build + lower + compile one cell; returns the record dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = step_kind or shape.kind
    rec = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(mesh.devices.shape)),
    }
    t0 = time.time()
    model = build(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = param_shardings(params_shapes, mesh, cfg.n_experts)

    with use_mesh(mesh):
        if kind == "train":
            _, train_step = make_train_step(cfg)
            opt_shapes = jax.eval_shape(init_opt, params_shapes)
            o_shard = jax.tree.map(
                lambda s: s, jax.eval_shape(init_opt, params_shapes))
            o_shard = param_shardings(opt_shapes, mesh, cfg.n_experts)
            batch = input_specs(cfg, shape)
            b_shard = batch_shardings(batch, mesh)
            jf = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            lowered = jf.lower(params_shapes, opt_shapes, batch)
        elif kind == "prefill":
            _, prefill_step = make_prefill_step(cfg)
            batch = input_specs(cfg, shape)
            batch.pop("labels", None)
            b_shard = batch_shardings(batch, mesh)
            jf = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
            lowered = jf.lower(params_shapes, batch)
        elif kind == "decode":
            _, serve_step = make_serve_step(cfg)
            mem_len = None
            cache_kwargs = {}
            if cfg.encoder_decoder:
                cache_kwargs["mem_len"] = shape.seq_len
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         dtype=jnp.bfloat16, **cache_kwargs))
            c_shard = cache_shardings(cache_shapes, mesh, shape.global_batch,
                                      cfg.n_kv_heads)
            inputs = decode_input_specs(cfg, shape)
            i_shard = batch_shardings(inputs, mesh)
            jf = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, i_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            )
            lowered = jf.lower(params_shapes, cache_shapes, inputs)
        else:
            raise ValueError(kind)

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    rec["memory"] = _mem_stats(compiled)
    rec["cost"] = _cost_stats(compiled)
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["hlo_lines"] = hlo.count("\n")
    rec["model_flops_global"] = model_flops(cfg, shape)
    return rec


def cell_path(arch: str, shape_name: str, multi_pod: bool) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    return OUT_DIR / f"{arch}__{shape_name}__{mesh}.json"


# ---------------------------------------------------------------------------
# cost probes: XLA cost_analysis counts while-loop (scan) bodies ONCE, so the
# scanned full-depth compiles under-count FLOPs/bytes/collectives by ~n_iters.
# Probes compile UNROLLED reduced-depth variants at two depths and the cell's
# true totals are the linear extrapolation (exact for homogeneous stacks):
#     cost(L) = base + per_layer * L
# ---------------------------------------------------------------------------

def probe_layer_pair(cfg):
    """Two reduced-depth configs + their n_layers, preserving structure."""
    import dataclasses as dc

    if cfg.local_global_ratio:          # gemma3: keep the remainder equal
        per = cfg.local_global_ratio + 1
        rem = cfg.n_layers % per
        l1, l2 = per + rem, 2 * per + rem
    elif cfg.hybrid_attn_every:
        per = cfg.hybrid_attn_every
        l1, l2 = per, 2 * per
    elif cfg.mlstm_slstm_pattern:
        per = cfg.mlstm_slstm_pattern + 1
        l1, l2 = per, 2 * per
    else:
        l1, l2 = 1, 2
    def mk(l):
        kw = {"n_layers": l}
        if cfg.encoder_decoder:
            kw["n_encoder_layers"] = l
        return dc.replace(cfg, **kw)
    return mk(l1), l1, mk(l2), l2


def _lower_probe(cfg, shape, kind, mesh):
    """Compile an unrolled reduced cfg; return (flops, bytes, coll_bytes)."""
    model = build(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = param_shardings(params_shapes, mesh, cfg.n_experts)
    with use_mesh(mesh):
        if kind == "train":
            _, step = make_train_step(cfg, unroll=True)
            opt_shapes = jax.eval_shape(init_opt, params_shapes)
            o_shard = param_shardings(opt_shapes, mesh, cfg.n_experts)
            batch = input_specs(cfg, shape)
            b_shard = batch_shardings(batch, mesh)
            jf = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
            compiled = jf.lower(params_shapes, opt_shapes, batch).compile()
        elif kind == "prefill":
            _, step = make_prefill_step(cfg, unroll=True)
            batch = input_specs(cfg, shape)
            batch.pop("labels", None)
            jf = jax.jit(step, in_shardings=(p_shard, batch_shardings(batch, mesh)))
            compiled = jf.lower(params_shapes, batch).compile()
        else:
            _, step = make_serve_step(cfg, unroll=True)
            kw = {"mem_len": shape.seq_len} if cfg.encoder_decoder else {}
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         dtype=jnp.bfloat16, **kw))
            c_shard = cache_shardings(cache_shapes, mesh, shape.global_batch,
                                      cfg.n_kv_heads)
            inputs = decode_input_specs(cfg, shape)
            jf = jax.jit(step, in_shardings=(p_shard, c_shard,
                                             batch_shardings(inputs, mesh)),
                         donate_argnums=(1,))
            compiled = jf.lower(params_shapes, cache_shapes, inputs).compile()
    c = _cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    return (float(c.get("flops", 0.0)), float(c.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]))


def run_probe(arch: str, shape_name: str, force: bool = False):
    path = OUT_DIR.parent / "probes" / f"{arch}__{shape_name}.json"
    if path.exists() and not force:
        prev = json.loads(path.read_text())
        if prev.get("status") == "ok":
            print(f"[skip] probe {path.name}")
            return prev
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    print(f"[probe] {arch} x {shape_name} ...", flush=True)
    try:
        t0 = time.time()
        c1, c2, scale = None, None, None
        cfg1, l1, cfg2, l2 = probe_layer_pair(cfg)
        c1 = _lower_probe(cfg1, shape, shape.kind, mesh)
        c2 = _lower_probe(cfg2, shape, shape.kind, mesh)
        scale = (cfg.n_layers - l1) / (l2 - l1)
        total = [a + scale * (b - a) for a, b in zip(c1, c2)]
        rec = {
            "arch": arch, "shape": shape_name, "status": "ok",
            "probe_layers": [l1, l2], "scale": scale,
            "flops": total[0], "bytes_accessed": total[1],
            "collective_bytes": total[2],
            "probe1": c1, "probe2": c2,
            "probe_s": round(time.time() - t0, 1),
        }
        print(f"  probe ok: flops/dev={total[0]:.3g} "
              f"coll/dev={total[2]:.3g}B ({rec['probe_s']}s)", flush=True)
    except Exception as e:  # noqa: BLE001
        rec = {"arch": arch, "shape": shape_name, "status": "error",
               "error": f"{type(e).__name__}: {e}"}
        print(f"  probe ERROR: {rec['error']}", flush=True)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1))
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False):
    path = cell_path(arch, shape_name, multi_pod)
    if path.exists() and not force:
        prev = json.loads(path.read_text())
        if prev.get("status") == "ok":   # error cells are retried
            print(f"[skip] {path.name} (ok)")
            return prev
    print(f"[dryrun] {arch} x {shape_name} x "
          f"{'2x16x16' if multi_pod else '16x16'} ...", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — failures are data here
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}"}
        print(f"  ERROR: {rec['error']}", flush=True)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1))
    if rec["status"] == "ok":
        print(f"  ok: compile={rec['compile_s']}s "
              f"flops/dev={rec['cost']['flops']:.3g} "
              f"coll={rec['collectives']['total_bytes']:.3g}B", flush=True)
    return rec


def all_cells():
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            if not shape_applicable(arch, shape_name):
                continue
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="run the unrolled cost probes instead of full cells")
    args = ap.parse_args()

    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    if args.probe:
        cells = (all_cells() if args.all else [(args.arch, args.shape)])
        for arch, shape_name in cells:
            run_probe(arch, shape_name, force=args.force)
        return

    if args.all:
        for arch, shape_name in all_cells():
            for mp in meshes:
                run_cell(arch, shape_name, mp, force=args.force)
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        for mp in meshes:
            run_cell(args.arch, args.shape, mp, force=args.force)


if __name__ == "__main__":
    main()
