import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver for the three selected cells.

Runs the unrolled cost probes under controlled variants and writes the
before/after table to experiments/results/hillclimb.json:

  * qwen2-72b x train_4k:     remat_policy full vs dots (#3)
  * minitron-4b x prefill_32k and llama4-scout x prefill_32k:
        current code (blocked attention #1 + heads-or-seq constraint #2)
        vs the dense baseline (constraint & blocking disabled via the
        attention module's threshold knob) — the "before" numbers are also
        preserved in experiments/probe_log.txt from the pre-change sweep.
"""

import dataclasses
import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import _lower_probe, probe_layer_pair
from repro.launch.mesh import make_production_mesh


def probe_total(cfg, shape_name: str):
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    cfg1, l1, cfg2, l2 = probe_layer_pair(cfg)
    c1 = _lower_probe(cfg1, shape, shape.kind, mesh)
    c2 = _lower_probe(cfg2, shape, shape.kind, mesh)
    scale = (cfg.n_layers - l1) / (l2 - l1)
    return [a + scale * (b - a) for a, b in zip(c1, c2)]


def main():
    out = {}
    from repro.models import attention as A

    # --- #1/#2: blocked attention + sharding constraint (prefill cells) ---
    for arch in ("minitron-4b", "llama4-scout-17b-a16e"):
        cfg = get_config(arch)
        new = probe_total(cfg, "prefill_32k")
        thr = A._BLOCK_THRESHOLD
        A._BLOCK_THRESHOLD = 1 << 30        # disable blocking+constraint
        try:
            old = probe_total(cfg, "prefill_32k")
        finally:
            A._BLOCK_THRESHOLD = thr
        out[f"{arch}__prefill_32k"] = {
            "dense_baseline": {"flops": old[0], "bytes": old[1], "coll": old[2]},
            "blocked+constraint": {"flops": new[0], "bytes": new[1], "coll": new[2]},
            "collective_reduction": old[2] / max(1.0, new[2]),
        }
        print(json.dumps(out[f"{arch}__prefill_32k"], indent=1), flush=True)

    # --- #3: remat policy (qwen2-72b train) -------------------------------
    cfg = get_config("qwen2-72b")
    full = probe_total(cfg, "train_4k")
    dots = probe_total(dataclasses.replace(cfg, remat_policy="dots"), "train_4k")
    out["qwen2-72b__train_4k"] = {
        "remat_full": {"flops": full[0], "bytes": full[1], "coll": full[2]},
        "remat_dots": {"flops": dots[0], "bytes": dots[1], "coll": dots[2]},
        "flops_reduction": full[0] / max(1.0, dots[0]),
    }
    print(json.dumps(out["qwen2-72b__train_4k"], indent=1), flush=True)

    path = Path(__file__).resolve().parents[3] / "experiments" / "results" / "hillclimb.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
