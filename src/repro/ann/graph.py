"""Graph ANN index (NSG/HNSW-like) with compressed friend lists.

Builders (faithful-in-statistics, DESIGN.md §9):
  * ``nsg``  — exact kNN graph + MRNG occlusion pruning (Fu et al. [20]);
  * ``hnsw`` — insertion order + heuristic neighbor selection with reverse
    edges, base layer only (Malkov & Yashunin [37]; the paper also
    compresses only the base layer, §5.3).

Online setting: per-node friend-list streams through any id codec.
Offline setting: the whole edge list through REC or webgraph-lite
(benchmarks/table3).  Search: ``search`` is the beam-batched engine
(repro.ann.graph_scan — lockstep frontier, shared decode, blocked
kernel scoring); ``search_ref`` keeps the per-query best-first loop as
the bit-exact oracle (what Table 2's NSG rows time).
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import List, Optional

import numpy as np

from ..core.codecs import get_codec
from .scan import CacheOwnerMixin, DecodedListCache
from .stats import SearchStats

__all__ = ["knn_graph", "build_nsg", "build_hnsw", "GraphIndex"]


def knn_graph(x: np.ndarray, k: int, chunk: int = 2048) -> np.ndarray:
    """Exact kNN (excluding self): returns (n, k) neighbor ids."""
    import jax
    import jax.numpy as jnp

    n = x.shape[0]
    xj = jnp.asarray(x, jnp.float32)
    sq = jnp.sum(xj * xj, axis=1)

    @jax.jit
    def topk_chunk(q):
        d = jnp.sum(q * q, 1, keepdims=True) - 2.0 * q @ xj.T + sq[None]
        _, idx = jax.lax.top_k(-d, k + 1)
        return idx

    out = np.zeros((n, k), np.int32)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        idx = np.asarray(topk_chunk(xj[lo:hi]))
        for r, row in enumerate(idx):
            row = row[row != lo + r][:k]
            out[lo + r, : len(row)] = row
    return out


def _occlusion_prune(x, cand: np.ndarray, center: int, r: int) -> List[int]:
    """MRNG rule: keep c if no kept neighbor is closer to c than center is."""
    kept: List[int] = []
    cd = np.sum((x[cand] - x[center]) ** 2, axis=1)
    order = np.argsort(cd)
    for ci in order:
        c = int(cand[ci])
        if c == center:
            continue
        ok = True
        for kpt in kept:
            if np.sum((x[c] - x[kpt]) ** 2) < cd[ci]:
                ok = False
                break
        if ok:
            kept.append(c)
            if len(kept) >= r:
                break
    return kept


def build_nsg(x: np.ndarray, r: int, knn_k: int = 0, seed: int = 0) -> List[np.ndarray]:
    """NSG-style adjacency (friend lists, <= r out-edges per node)."""
    knn_k = knn_k or min(max(2 * r, 16), 64)
    nn = knn_graph(x, knn_k)
    n = x.shape[0]
    adj = []
    for i in range(n):
        kept = _occlusion_prune(x, nn[i], i, r)
        adj.append(np.asarray(sorted(kept), np.int64))
    return adj


def build_hnsw(x: np.ndarray, m: int, seed: int = 0) -> List[np.ndarray]:
    """HNSW-ish base layer: kNN candidates + heuristic + reverse edges."""
    nn = knn_graph(x, min(2 * m, 48))
    n = x.shape[0]
    adj = [list() for _ in range(n)]
    for i in range(n):
        kept = _occlusion_prune(x, nn[i], i, m)
        adj[i] = list(kept)
    # add reverse edges up to the cap (HNSW's bidirectional insertion)
    for i in range(n):
        for j in adj[i]:
            if len(adj[j]) < m and i not in adj[j]:
                adj[j].append(i)
    return [np.asarray(sorted(set(a)), np.int64) for a in adj]


@dataclasses.dataclass
class GraphIndex(CacheOwnerMixin):
    id_codec: str = "roc"
    cache_bytes: Optional[int] = None    # DecodedListCache budget (None = default)
    cache_policy: str = "lru"            # "lru" | "2q"
    max_epochs: Optional[int] = None     # auto-compact past this universe count

    def build(self, x: np.ndarray, adj: List[np.ndarray]) -> "GraphIndex":
        self.x = x.astype(np.float32)
        self.n = x.shape[0]
        self.adj_raw = adj
        codec = get_codec(self.id_codec)
        self._codec = codec
        self._blobs = [codec.encode(a, self.n) if len(a) else None for a in adj]
        # per-node encoding universe — the graph analogue of the IVF epoch
        # scheme: a blob decodes against the universe it was sealed at, so
        # appends only re-encode the nodes they actually touch
        self._universes = np.full(self.n, self.n, np.int64)
        # entry point: medoid
        mean = self.x.mean(0)
        self.entry = int(np.argmin(np.sum((self.x - mean) ** 2, axis=1)))
        self._decoded_cache = self._new_cache()
        return self

    def add(self, x_new: np.ndarray, r: int = 16) -> "GraphIndex":
        """Incremental HNSW-style insertion of new vectors.

        Each new node gets <= ``r`` out-edges via the same occlusion rule
        the offline builders use (candidates = nearest existing nodes),
        plus reverse edges on its neighbors up to the ``r`` cap.  Only the
        *touched* friend lists re-encode — new nodes, plus existing nodes
        that gained a reverse edge — at the grown universe; every other
        blob keeps its original universe (recorded in ``_universes``) and
        stays byte-identical, so ingest is O(Δ · degree), not O(n).  Only
        the touched nodes' cache entries are invalidated.
        """
        x_new = np.asarray(x_new, np.float32)
        if x_new.ndim == 1:
            x_new = x_new[None]
        if x_new.shape[0] == 0:
            return self
        touched: set = set()
        for row in x_new:
            i = self.n
            self.x = np.concatenate([self.x, row[None]], axis=0)
            d = np.sum((self.x[:i] - row) ** 2, axis=1)
            cand = np.argsort(d, kind="stable")[: max(2 * r, 16)]
            kept = _occlusion_prune(self.x, cand, i, r)
            self.n = i + 1
            self.adj_raw.append(np.asarray(sorted(kept), np.int64))
            self._blobs.append(None)
            for j in kept:
                if len(self.adj_raw[j]) < r and i not in self.adj_raw[j]:
                    self.adj_raw[j] = np.asarray(
                        sorted(np.append(self.adj_raw[j], i)), np.int64)
                    touched.add(int(j))
        touched.update(range(self.n - x_new.shape[0], self.n))
        self._universes = np.concatenate(
            [self._universes, np.full(x_new.shape[0], self.n, np.int64)])
        for i in sorted(touched):
            a = self.adj_raw[i]
            self._blobs[i] = self._codec.encode(a, self.n) if len(a) else None
            self._universes[i] = self.n
            self.decoded_cache.invalidate(i)
        if (self.max_epochs is not None
                and self.n_epochs > self.max_epochs):
            self.compact()
        return self

    @property
    def n_epochs(self) -> int:
        """Distinct encoding universes currently live (1 after compact)."""
        return int(np.unique(self._universes).size)

    def compact(self) -> "GraphIndex":
        """Re-encode every friend list at the current universe.

        Collapses ``_universes`` to a single value — the offline builders'
        rates again — at O(n) cost; run off the ingest path."""
        self._blobs = [self._codec.encode(a, self.n) if len(a) else None
                       for a in self.adj_raw]
        self._universes = np.full(self.n, self.n, np.int64)
        self.decoded_cache.clear()
        return self

    def id_bits(self) -> int:
        return int(sum(self._codec.size_bits(b) for b in self._blobs if b is not None))

    def bits_per_edge(self) -> float:
        edges = sum(len(a) for a in self.adj_raw)
        return self.id_bits() / max(1, edges)

    def _friends(self, i: int) -> np.ndarray:
        """Friend list of node ``i``, decoded through the LRU cache."""
        blob = self._blobs[i]
        if blob is None:
            return np.zeros(0, np.int64)
        universe = int(self._universes[i])
        return self.decoded_cache.get(
            i, lambda: np.asarray(self._codec.decode(blob, universe)))

    def search(self, queries: np.ndarray, ef: int = 16, topk: int = 10,
               engine: str = "auto", query_block: int = 64,
               kernel_min: int | None = None, select: str = "auto"):
        """Beam-batched search (repro.ann.graph_scan).

        Advances all queries in lockstep: per-step deduped friend-list
        gather through the shared decode cache, one blocked distance
        computation per step (``engine`` picks the Pallas kernel or the
        jitted XLA fallback; ``kernel_min`` gates the minimum tile that
        takes it; ``select`` places the per-step distance gather host- or
        device-side), exact beam admission.  Bit-identical to
        :meth:`search_ref` — ids AND distances — for every codec, engine
        and select mode.
        """
        from .graph_scan import batched_graph_search

        return batched_graph_search(self, queries, ef=ef, topk=topk,
                                    engine=engine, query_block=query_block,
                                    kernel_min=kernel_min, select=select)

    def search_ref(self, queries: np.ndarray, ef: int = 16, topk: int = 10):
        """Best-first (beam ef) search decoding friend lists on the fly.

        The original per-query Python loop, kept as the batched engine's
        bit-exact oracle (same contract as ``IVFIndex.search_ref``).
        Returns ``(ids, dists, SearchStats)`` — the same shape as
        ``IVFIndex.search`` so services and benchmarks aggregate uniformly
        (``visited`` = nodes expanded, ``decodes`` = friend-list decode
        events, ``ndis`` = distance evaluations).
        """
        t0 = time.perf_counter()
        nq = queries.shape[0]
        ids = np.zeros((nq, topk), np.int64)
        dists = np.full((nq, topk), np.inf, np.float32)
        hops = 0
        ndis = 0
        decodes0 = self.decoded_cache.decodes
        for qi in range(nq):
            q = queries[qi]
            visited = {self.entry}
            d0 = float(np.sum((self.x[self.entry] - q) ** 2))
            ndis += 1
            cand = [(d0, self.entry)]           # min-heap of frontier
            best = [(-d0, self.entry)]          # max-heap of results (size ef)
            while cand:
                d, u = heapq.heappop(cand)
                if d > -best[0][0] and len(best) >= ef:
                    break
                hops += 1
                friends = self._friends(u)
                new = [v for v in friends if v not in visited]
                visited.update(new)
                if not new:
                    continue
                dv = np.sum((self.x[new] - q) ** 2, axis=1)
                ndis += len(new)
                for v, dd in zip(new, dv):
                    dd = float(dd)
                    if len(best) < ef or dd < -best[0][0]:
                        heapq.heappush(cand, (dd, int(v)))
                        heapq.heappush(best, (-dd, int(v)))
                        if len(best) > ef:
                            heapq.heappop(best)
            res = sorted([(-d, v) for d, v in best])[:topk]
            for j, (dd, v) in enumerate(res):
                ids[qi, j] = v
                dists[qi, j] = dd
        stats = SearchStats(
            wall_s=time.perf_counter() - t0,
            ndis=ndis,
            id_resolve_s=0.0,
            decodes=self.decoded_cache.decodes - decodes0,
            engine="graph",
            visited=hops,
        )
        return ids, dists, stats
