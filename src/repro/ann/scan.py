"""Batched compressed-IVF scan engine — the paper's §4.1 at batch scale.

``IVFIndex.search_ref`` scans one query and one probed cluster at a time in
Python; fine as a correctness oracle, useless for throughput and for
measuring the paper's headline claim (id compression costs *no* search
runtime).  This module is the batched replacement, the blocked-scan layer
Faiss and Zoom get their throughput from:

1. **Coarse probe** for the whole query batch at once (one distance matrix
   against the centroids, shared with the oracle so probe sets are
   bit-identical).
2. **Cluster dedup + arena gather**: the union of probed clusters across a
   query block is gathered once into a contiguous "arena" of vectors / PQ
   codes (each cluster appears once however many queries probe it).
3. **Blocked scoring** of the query block against the arena through the
   Pallas kernels (``l2_dist`` / ``pq_adc``; interpret-mode on CPU) or a
   pure-XLA fallback — both jitted once per bucketed shape.
4. **Exact top-k**: the short-list within the kernel-error band of the
   (topk + ``RESCORE_SLACK``)-th best kernel distance is re-scored with
   the *same numpy scalar path the oracle uses*, so returned ids **and
   distances** are bit-identical to ``search_ref`` (kernel float error
   only reorders the short-list, never the result).  The short-list is
   cut either host-side (a stable masked argsort over the pulled
   ``(qb, C_pad)`` block) or **device-side** (``select="device"``): a
   jitted candidate gather + segmented top-k (``repro.kernels.seg_topk``)
   runs on device and only ``(qb, K)`` shortlist values/offsets cross to
   the host — never the padded block (``stats.host_block_bytes`` /
   ``stats.device_select`` are the ledger).  Both cuts produce the same
   short-list *set*, so results are bit-identical across
   ``select`` × ``engine``.
5. **Vectorized late id resolution** (§4.1): the winning ``(cluster,
   offset)`` pairs of all queries are resolved in one pass — per-cluster
   decode through an LRU :class:`DecodedListCache` for stream codecs
   (ROC/gap-ANS), random ``access`` for EF/compact/uncompressed, ``select``
   for wavelet trees.  Each needed cluster is decoded at most once per
   batch (and usually zero times once the cache is warm).

Batching contract: results are a pure function of (index, queries, nprobe,
topk) — independent of ``query_block``, engine choice, and cache state.
Only the stats differ.
"""

from __future__ import annotations

import functools
import time
from collections import OrderedDict
from typing import Callable, Dict, List

import numpy as np

__all__ = [
    "batched_search",
    "batched_flat_search",
    "MERGE_KEY_PAD",
    "coarse_probes",
    "select_topk",
    "score_rows_flat",
    "resolve_ids_batch",
    "rescore_eps",
    "pack_merge_keys",
    "DecodedListCache",
    "CacheOwnerMixin",
]

# extra short-list entries re-scored exactly: kernel scoring only has to get
# the top-k *set* right up to this slack, never the exact float ordering.
RESCORE_SLACK = 8
DEFAULT_QUERY_BLOCK = 64
# select="auto" tile gate (the kernel_min analogue): on CPU the host numpy
# select competes with an interpreted/jitted device select plus its dispatch,
# so only candidate rows at least this wide take the device path; off-CPU
# auto always selects on device.
SELECT_MIN_CPU = 4096


# ---------------------------------------------------------------------------
# shared numpy primitives (used by BOTH search_ref and the batched engine so
# parity is by construction)
# ---------------------------------------------------------------------------

def coarse_probes(queries: np.ndarray, centroids: np.ndarray,
                  nprobe: int) -> np.ndarray:
    """(nq, min(nprobe, nlist)) probed clusters, nearest first, stable ties."""
    qc = (
        np.sum(queries**2, 1, keepdims=True)
        - 2.0 * queries @ centroids.T
        + np.sum(centroids**2, 1)[None]
    )
    nprobe = min(nprobe, centroids.shape[0])
    return np.argsort(qc, axis=1, kind="stable")[:, :nprobe]


def select_topk(d: np.ndarray, topk: int) -> np.ndarray:
    """Indices of the ``topk`` smallest entries, ties to the earlier index."""
    return np.argsort(d, kind="stable")[: min(topk, d.shape[0])]


def score_rows_flat(rows: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Squared L2 of each row to ``q`` — the oracle's scalar scoring path."""
    diff = rows - q[None]
    return np.einsum("nd,nd->n", diff, diff)


def rescore_eps(d: int, bound: float, qn: float, factor: float = 16.0) -> float:
    """Error band of the kernels' expanded ``qn - 2qc + cn`` f32 scoring.

    The expanded form cancels catastrophically for near-duplicate vectors,
    so kernel distances near a decision ``bound`` may be mis-ranked by up
    to the cancellation error; exact decisions must re-score everything
    within this band.  ``factor`` carries headroom over the d-term f32
    contraction bound — too wide only re-scores a few extra rows, never
    breaks parity.  Shared by the IVF shortlist extension and the graph
    engine's beam-admission pruning so both use one audited bound.
    """
    scale = 1.0 + abs(float(bound)) + float(qn)
    return factor * d * float(np.finfo(np.float32).eps) * scale


# ---------------------------------------------------------------------------
# decoded-list LRU cache
# ---------------------------------------------------------------------------

class DecodedListCache:
    """Byte-budgeted cache over decoded id lists, LRU or 2Q.

    ``resolve_ids`` used to rebuild its decode cache per call; this one
    lives on the index, so a warm serving loop decodes each hot cluster
    once, not once per request batch.

    ``policy="lru"`` (default) is plain recency eviction.  ``policy="2q"``
    is a segmented LRU: first touch lands an entry in a *probation*
    segment, a second touch promotes it to a *protected* segment (capped
    at ``HOT_FRACTION`` of the budget, demoting its own LRU tail back to
    probation), and eviction always drains probation first — so a scan
    over many cold clusters can no longer flush the clusters that skewed
    query traffic keeps hot.

    Keys are any hashables: the IVF path uses ``(epoch, cluster)`` pairs,
    the graph path uses node ids — appends create fresh keys and never
    alias warm ones, so ingest needs no cache invalidation at all (only
    compaction, which renumbers epochs, calls :meth:`clear`).
    """

    HOT_FRACTION = 0.75

    def __init__(self, max_bytes: int = 64 << 20, policy: str = "lru"):
        if policy not in ("lru", "2q"):
            raise ValueError(f"unknown cache policy {policy!r} "
                             "(options: lru, 2q)")
        self.max_bytes = int(max_bytes)
        self.policy = policy
        self._lists: "OrderedDict[object, np.ndarray]" = OrderedDict()
        self._hot: "OrderedDict[object, np.ndarray]" = OrderedDict()
        self._hot_bytes = 0
        self.bytes = 0
        self.hits = 0
        self.decodes = 0
        self.evictions = 0
        self.promotions = 0

    def __len__(self) -> int:
        return len(self._lists) + len(self._hot)

    def _evict(self) -> None:
        # probation (or the sole LRU segment) drains first; the protected
        # segment is only touched once probation is empty
        while self.bytes > self.max_bytes and len(self) > 1:
            if self._lists:
                _, old = self._lists.popitem(last=False)
            else:
                _, old = self._hot.popitem(last=False)
                self._hot_bytes -= old.nbytes
            self.bytes -= old.nbytes
            self.evictions += 1

    def _shrink_hot(self) -> None:
        cap = self.HOT_FRACTION * self.max_bytes
        while self._hot_bytes > cap and len(self._hot) > 1:
            key, old = self._hot.popitem(last=False)
            self._hot_bytes -= old.nbytes
            self._lists[key] = old          # demote to probation MRU

    def get(self, key, decode: Callable[[], np.ndarray]) -> np.ndarray:
        hot = self._hot.get(key)
        if hot is not None:
            self._hot.move_to_end(key)
            self.hits += 1
            return hot
        hit = self._lists.get(key)
        if hit is not None:
            self.hits += 1
            if self.policy == "2q":
                del self._lists[key]        # second touch: promote
                self._hot[key] = hit
                self._hot_bytes += hit.nbytes
                self.promotions += 1
                self._shrink_hot()
            else:
                self._lists.move_to_end(key)
            return hit
        arr = np.asarray(decode())
        self.decodes += 1
        self._lists[key] = arr
        self.bytes += arr.nbytes
        self._evict()
        return arr

    def invalidate(self, key) -> None:
        """Drop one entry (not counted as an eviction); no-op if absent."""
        old = self._lists.pop(key, None)
        if old is None:
            old = self._hot.pop(key, None)
            if old is not None:
                self._hot_bytes -= old.nbytes
        if old is not None:
            self.bytes -= old.nbytes

    def clear(self) -> None:
        self._lists.clear()
        self._hot.clear()
        self._hot_bytes = 0
        self.bytes = 0

    def set_budget(self, max_bytes: int) -> None:
        """Change the byte budget, evicting entries down to it."""
        self.max_bytes = int(max_bytes)
        self._evict()
        if self.policy == "2q":
            self._shrink_hot()

    def stats(self) -> Dict[str, int]:
        out = {
            "entries": len(self),
            "bytes": self.bytes,
            "hits": self.hits,
            "decodes": self.decodes,
            "evictions": self.evictions,
        }
        if self.policy == "2q":
            out["promotions"] = self.promotions
            out["protected_entries"] = len(self._hot)
        return out


class CacheOwnerMixin:
    """Cache plumbing shared by ``IVFIndex`` and ``GraphIndex``.

    Builds the :class:`DecodedListCache` from the owner's declared
    ``cache_bytes`` / ``cache_policy`` fields, and re-attaches one on
    unpickle (``__setstate__``) so indexes pickled before the cache —
    or before the ``cache_policy`` field — existed keep working without
    per-access ``hasattr`` checks.
    """

    def _new_cache(self) -> DecodedListCache:
        budget = getattr(self, "cache_bytes", None)
        policy = getattr(self, "cache_policy", None) or "lru"
        if budget is not None:
            return DecodedListCache(max_bytes=int(budget), policy=policy)
        return DecodedListCache(policy=policy)

    @property
    def decoded_cache(self) -> DecodedListCache:
        return self._decoded_cache

    def __getstate__(self) -> dict:
        state = dict(self.__dict__)
        state.pop("_decoded_cache", None)   # transient derived state
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        if "_decoded_cache" not in self.__dict__:
            self._decoded_cache = self._new_cache()


# ---------------------------------------------------------------------------
# vectorized late id resolution (§4.1)
# ---------------------------------------------------------------------------

def resolve_ids_batch(index, clusters: np.ndarray,
                      offsets: np.ndarray) -> np.ndarray:
    """Resolve all ``(cluster, offset)`` pairs in one pass.

    Offsets are positions in the logical (all-epochs) cluster list; the
    index's :class:`repro.core.epoch.EpochStore` routes each pair to its
    epoch and resolves it there — stream codecs (ROC/gap-ANS) decode each
    distinct ``(epoch, cluster)`` at most once per call through the
    index's :class:`DecodedListCache`; EF/compact/uncompressed use random
    access; wavelet trees use ``select``.
    """
    return index._ids.resolve(clusters, offsets, index.decoded_cache)


# ---------------------------------------------------------------------------
# jitted scoring backends
# ---------------------------------------------------------------------------

def _bucket(n: int, floor: int = 1024) -> int:
    """Next power-of-two >= n (floored) — bounds jit retraces per shape."""
    b = floor
    while b < n:
        b *= 2
    return b


@functools.lru_cache(maxsize=None)
def _jax():
    import jax  # deferred so numpy-only use of the index never imports jax

    return jax


@functools.lru_cache(maxsize=None)
def _flat_scorers():
    jax, jnp = _jax(), _jax().numpy

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def pallas(q, a, interpret=True):
        from ..kernels.l2_topk import l2_dist

        return l2_dist(q, a, interpret=interpret)

    @jax.jit
    def xla(q, a):
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        an = jnp.sum(a * a, axis=1)
        return qn - 2.0 * q @ a.T + an[None]

    return {"pallas": pallas, "xla": xla}


@functools.lru_cache(maxsize=None)
def _adc_scorers():
    jax, jnp = _jax(), _jax().numpy

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def pallas(luts, codes, interpret=True):
        from ..kernels.pq_adc import pq_adc

        # vmap over per-query LUTs; codes (the arena) are shared.
        return jax.vmap(
            lambda lut: pq_adc(codes, lut, interpret=interpret)
        )(luts)

    @jax.jit
    def xla(luts, codes):
        m = codes.shape[1]
        sub = jnp.arange(m)[None, :]

        # sequential over queries: keeps peak memory at one (U, m) gather
        # instead of materializing the (QB, U, m) cube.
        def one(lut):
            return lut[sub, codes].sum(axis=1).astype(jnp.float32)

        return jax.lax.map(one, luts)

    return {"pallas": pallas, "xla": xla}


@functools.lru_cache(maxsize=None)
def _device_selector():
    """Jitted candidate gather + segmented top-k, fused on device.

    From the tiny per-block metadata (probed clusters per query, arena
    span start/size per cluster) the candidate->arena-position map is
    recomputed on device, the scored block is gathered in place, and the
    segmented top-k (``repro.kernels.seg_topk``) cuts each row to its
    ``k`` smallest ``(value, column)`` pairs — so the ``(qb, C_pad)``
    distance block never crosses the device boundary; only ``(qb, k)``
    values, candidate columns and arena positions return to the host.
    """
    jax, jnp = _jax(), _jax().numpy

    @functools.partial(jax.jit,
                       static_argnames=("c_pad", "k", "engine", "interpret"))
    def run(dmat, probes, start_of, size_of, c_pad, k, engine, interpret):
        from ..kernels.seg_topk import seg_topk, seg_topk_xla

        pp = size_of[probes]                       # (qb_pad, P)
        cum = jnp.cumsum(pp, axis=1)
        col = jnp.arange(c_pad, dtype=jnp.int32)
        # probe owning each candidate column: count of probe-end offsets
        # <= col (side="right" skips zero-size probes, matching the host
        # _spans_concat concatenation exactly)
        pidx = jax.vmap(lambda c: jnp.searchsorted(c, col, side="right"))(cum)
        total = cum[:, -1][:, None]
        valid = col[None, :] < total
        pc = jnp.minimum(pidx, pp.shape[1] - 1)
        prev = jnp.where(
            pidx > 0,
            jnp.take_along_axis(cum, jnp.maximum(pidx, 1) - 1, axis=1), 0)
        cl = jnp.take_along_axis(probes, pc, axis=1)
        pos = start_of[cl] + (col[None, :] - prev)
        pos = jnp.clip(pos, 0, dmat.shape[1] - 1).astype(jnp.int32)
        d = jnp.where(valid, jnp.take_along_axis(dmat, pos, axis=1),
                      jnp.inf)
        lens = jnp.minimum(total[:, 0], c_pad).astype(jnp.int32)
        if engine == "pallas":
            vals, cols = seg_topk(d, lens, k, interpret=interpret)
        else:
            vals, cols = seg_topk_xla(d, lens, k)
        pos_sel = jnp.take_along_axis(pos, cols, axis=1)
        return vals, cols, pos_sel

    return run


def _resolve_select(select: str, c_pad: int, select_min: int) -> bool:
    """True when this block's top-k runs on device (see ``batched_search``)."""
    if select == "host":
        return False
    if select == "device":
        return True
    if select != "auto":
        raise ValueError(f"unknown select mode {select!r} "
                         "(options: auto, host, device)")
    return c_pad >= select_min


def _resolve_engine(engine: str) -> str:
    if engine == "auto":
        try:
            backend = _jax().default_backend()
        except (ImportError, RuntimeError):  # pragma: no cover - no backend
            backend = "cpu"
        # interpret-mode Pallas is a correctness path, not a fast path:
        # on CPU the plain-XLA scorer is the performant batched fallback.
        return "pallas" if backend != "cpu" else "xla"
    if engine not in ("pallas", "xla"):
        raise ValueError(f"unknown scan engine {engine!r}")
    return engine


# ---------------------------------------------------------------------------
# the batched search
# ---------------------------------------------------------------------------

def _spans_concat(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """concat(arange(s, s+l) for s, l in zip(starts, lens)) without a loop."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    cum = np.cumsum(lens) - lens
    idx = np.arange(total, dtype=np.int64)
    return np.repeat(starts - cum, lens) + idx


MERGE_KEY_PAD = np.uint64(np.iinfo(np.uint64).max)

# merge-key layout: (probe_rank << 40) | in-cluster offset.  40 offset bits
# cap any single cluster at 2^40 rows; the remaining 24 rank bits cap nprobe
# at 2^24.  Both are astronomically past realistic shapes, but a silent
# wrap would corrupt the sharded merge order, so packing checks explicitly.
MERGE_KEY_OFFSET_BITS = 40
MERGE_KEY_RANK_BITS = 64 - MERGE_KEY_OFFSET_BITS


def pack_merge_keys(ranks: np.ndarray, offs: np.ndarray) -> np.ndarray:
    """``(probe_rank << 40) | offset`` uint64 tie-order keys, overflow-checked.

    Raises ``OverflowError`` instead of silently wrapping: an offset at or
    above ``2^40`` would leak into the rank field and a rank at or above
    ``2^24`` would wrap off the top, either of which reorders the sharded
    router's ``(dist, key)`` merge.
    """
    ranks = np.asarray(ranks, np.uint64)
    offs = np.asarray(offs, np.uint64)
    if offs.size and int(offs.max()) >= (1 << MERGE_KEY_OFFSET_BITS):
        raise OverflowError(
            f"in-cluster offset {int(offs.max())} needs more than "
            f"{MERGE_KEY_OFFSET_BITS} merge-key bits")
    if ranks.size and int(ranks.max()) >= (1 << MERGE_KEY_RANK_BITS):
        raise OverflowError(
            f"probe rank {int(ranks.max())} needs more than "
            f"{MERGE_KEY_RANK_BITS} merge-key bits")
    return (ranks << np.uint64(MERGE_KEY_OFFSET_BITS)) | offs


def batched_search(index, queries: np.ndarray, nprobe: int = 16,
                   topk: int = 10, engine: str = "auto",
                   query_block: int = DEFAULT_QUERY_BLOCK,
                   with_keys: bool = False, select: str = "auto",
                   select_min: int | None = None):
    """Batched IVF search; bit-identical to ``index.search_ref``.

    Returns ``(ids (nq, topk) int64, dists (nq, topk) f32, SearchStats)``.

    ``select`` places the top-k cut: ``"host"`` pulls the scored
    ``(qb, C_pad)`` block and argsorts in numpy; ``"device"`` runs the
    jitted gather + segmented top-k (``repro.kernels.seg_topk``, same
    ``engine`` choice as the scorer) so only ``(qb, K)`` shortlists cross
    to the host; ``"auto"`` takes the device path when the candidate row
    is at least ``select_min`` wide (default: ``SELECT_MIN_CPU`` on CPU,
    always on accelerators).  Both paths cut the *same* short-list set —
    every candidate within the kernel-error band of the
    (topk + ``RESCORE_SLACK``)-th best kernel distance — and the exact
    re-score decides, so results are bit-identical across
    ``select`` × ``engine``; only ``stats.host_block_bytes`` /
    ``stats.device_select`` differ.

    ``with_keys=True`` additionally fills ``stats.merge_keys`` with a
    (nq, topk) uint64 array: each result's position in the monolithic
    stable candidate order, ``(probe_rank << 40) | in-cluster offset``
    (padding slots = ``MERGE_KEY_PAD``).  Candidates of one query are
    concatenated probe-by-probe then offset-by-offset, so this key is
    exactly the order ``select_topk`` breaks distance ties with — a
    sharded router that merges per-shard results by ``(dist, key)``
    reproduces the unsharded output bit-for-bit even under duplicate
    vectors (repro.shard.service).
    """
    from .pq import ProductQuantizer
    from .stats import SearchStats

    jnp = _jax().numpy
    engine = _resolve_engine(engine)
    if select not in ("auto", "host", "device"):
        raise ValueError(f"unknown select mode {select!r} "
                         "(options: auto, host, device)")
    t0 = time.perf_counter()
    queries = np.asarray(queries)
    nq = queries.shape[0]
    all_ids = np.zeros((nq, topk), np.int64)
    all_d = np.full((nq, topk), np.inf, np.float32)
    probes = coarse_probes(queries, index.centroids, nprobe)
    tables = index.pq.adc_tables(queries) if index.pq is not None else None
    use_pq = index.pq is not None
    interpret = _jax().default_backend() == "cpu"
    if select_min is None:
        select_min = SELECT_MIN_CPU if interpret else 1

    offsets, sizes = index.offsets, index.sizes
    ndis = 0
    nbatches = 0
    host_block_bytes = 0
    n_dev_select = 0
    distinct: set = set()
    decodes_before = index.decoded_cache.decodes
    # winning (cluster, offset) pairs across the whole call, resolved in one
    # pass at the end
    res_q: List[np.ndarray] = []
    res_slot: List[np.ndarray] = []
    res_cluster: List[np.ndarray] = []
    res_offset: List[np.ndarray] = []
    res_key: List[np.ndarray] = []
    all_keys = (np.full((nq, topk), MERGE_KEY_PAD, np.uint64)
                if with_keys else None)

    for q0 in range(0, nq, query_block):
        q1 = min(nq, q0 + query_block)
        qb = q1 - q0
        nbatches += 1
        blk_probes = probes[q0:q1]
        # --- dedup probed clusters; build the arena ------------------------
        uniq = np.unique(blk_probes)
        uniq_sizes = sizes[uniq].astype(np.int64)
        keep = uniq_sizes > 0
        uniq, uniq_sizes = uniq[keep], uniq_sizes[keep]
        distinct.update(int(k) for k in uniq)
        arena_start = np.cumsum(uniq_sizes) - uniq_sizes
        u_rows = int(uniq_sizes.sum())
        arena_rows = _spans_concat(offsets[uniq], uniq_sizes)
        # cluster id -> arena span start (dense map over probed ids only)
        start_of = np.full(index.nlist, -1, dtype=np.int64)
        size_of = np.zeros(index.nlist, dtype=np.int64)
        start_of[uniq] = arena_start
        size_of[uniq] = uniq_sizes
        if with_keys:
            # probe rank of each cluster per query (same for every shard of a
            # shared-quantizer plan, since probes only depend on centroids)
            rank_of = np.zeros((qb, index.nlist), np.uint64)
            rank_of[np.arange(qb)[:, None], blk_probes] = np.arange(
                blk_probes.shape[1], dtype=np.uint64)[None]

        # --- per-query padded candidate rows (probe order == oracle order) -
        pp_sizes = size_of[blk_probes]              # (qb, P)
        cand_lens = pp_sizes.sum(axis=1)
        ndis += int(cand_lens.sum())
        c_pad = int(cand_lens.max()) if qb else 0
        if c_pad == 0:
            continue
        flat_pos = _spans_concat(start_of[blk_probes].ravel(),
                                 pp_sizes.ravel())
        cand_pos = np.full((qb, c_pad), -1, dtype=np.int64)
        row_ids = np.repeat(np.arange(qb), cand_lens)
        col_ids = np.concatenate(
            [np.arange(c) for c in cand_lens]
        ) if qb else np.zeros(0, np.int64)
        cand_pos[row_ids, col_ids] = flat_pos

        # --- blocked scoring ----------------------------------------------
        # bucketed padding (not fixed query_block): a max-wait flush of a few
        # queries must not score query_block-worth of phantom LUTs/rows
        u_pad = _bucket(u_rows)
        qb_pad = _bucket(qb, floor=8)
        if use_pq:
            arena = np.zeros((u_pad, index.codes.shape[1]),
                             index.codes.dtype)
            arena[:u_rows] = index.codes[arena_rows]
            luts = np.zeros((qb_pad,) + tables.shape[1:], np.float32)
            luts[:qb] = tables[q0:q1]
            scorer = _adc_scorers()[engine]
            if engine == "pallas":
                dmat = scorer(jnp.asarray(luts), jnp.asarray(arena),
                              interpret=interpret)
            else:
                dmat = scorer(jnp.asarray(luts), jnp.asarray(arena))
        else:
            arena = np.zeros((u_pad, index.d), np.float32)
            arena[:u_rows] = index.vecs[arena_rows]
            qblk = np.zeros((qb_pad, index.d), np.float32)
            qblk[:qb] = queries[q0:q1]
            scorer = _flat_scorers()[engine]
            if engine == "pallas":
                dmat = scorer(jnp.asarray(qblk), jnp.asarray(arena),
                              interpret=interpret)
            else:
                dmat = scorer(jnp.asarray(qblk), jnp.asarray(arena))
        if not use_pq:
            qn_host = np.einsum("qd,qd->q",
                                queries[q0:q1].astype(np.float32),
                                queries[q0:q1].astype(np.float32))

        def finish(i, qi, pos):
            # exact re-score of one query's short-list; ``pos`` holds the
            # selected arena positions in candidate (oracle concat) order,
            # so select_topk's stable tie-break reproduces the oracle's.
            rows = arena_rows[pos]
            if use_pq:
                d_exact = ProductQuantizer.adc_score(
                    index.codes[rows], tables[qi])
            else:
                d_exact = score_rows_flat(index.vecs[rows], queries[qi])
            best = select_topk(d_exact, topk)
            n_found = best.shape[0]
            all_d[qi, :n_found] = d_exact[best]
            # (cluster, offset) from arena position
            p = pos[best]
            span = np.searchsorted(arena_start, p, side="right") - 1
            res_q.append(np.full(n_found, qi, np.int64))
            res_slot.append(np.arange(n_found, dtype=np.int64))
            res_cluster.append(uniq[span])
            res_offset.append(p - arena_start[span])
            if with_keys:
                res_key.append(pack_merge_keys(rank_of[i, uniq[span]],
                                               p - arena_start[span]))

        if _resolve_select(select, c_pad, select_min):
            # --- device-side segmented top-k -------------------------------
            # the (qb, C_pad) block stays on device: a jitted gather +
            # seg_topk returns (qb, K) shortlist values / candidate columns
            # / arena positions, the host recomputes the SAME short-list
            # threshold the host path uses (bound of the take-th smallest
            # kernel value + rescore_eps, in float64 over identical f32
            # values), and K doubles while any row's shortlist might extend
            # past it — so the cut set matches the host path exactly.
            n_dev_select += 1
            runner = _device_selector()
            c_pad_b = _bucket(c_pad, floor=128)
            probes_pad = np.zeros((qb_pad, blk_probes.shape[1]), np.int32)
            probes_pad[:qb] = blk_probes
            start32 = np.maximum(start_of, 0).astype(np.int32)
            size32 = size_of.astype(np.int32)
            K = min(_bucket(min(topk + RESCORE_SLACK, c_pad), floor=16),
                    c_pad_b)
            while True:
                vals_d, cols_d, pos_d = runner(
                    dmat, jnp.asarray(probes_pad), jnp.asarray(start32),
                    jnp.asarray(size32), c_pad=c_pad_b, k=K, engine=engine,
                    interpret=interpret)
                vals = np.asarray(vals_d)
                sel_cols = np.asarray(cols_d)
                sel_pos = np.asarray(pos_d)
                host_block_bytes += (vals.nbytes + sel_cols.nbytes
                                     + sel_pos.nbytes)
                vals = vals[:qb]
                thr = np.full(qb, -np.inf)
                retry = False
                for i in range(qb):
                    nvalid = int(cand_lens[i])
                    if nvalid == 0:
                        continue
                    take = min(topk + RESCORE_SLACK, nvalid)
                    bound = float(vals[i, take - 1])
                    eps = rescore_eps(index.d, bound,
                                      0.0 if use_pq else float(qn_host[i]))
                    thr[i] = bound + eps
                    if nvalid > K and vals[i, K - 1] <= thr[i]:
                        retry = True    # band may extend past the K cut
                if not retry or K >= c_pad_b:
                    break
                K = min(2 * K, c_pad_b)
            for i in range(qb):
                qi = q0 + i
                nvalid = int(cand_lens[i])
                if nvalid == 0:
                    continue
                # vals are ascending: count the entries inside the band,
                # drop padding columns (>= nvalid; real +inf hits keep
                # their column < nvalid), restore oracle concat order
                cnt = int(np.searchsorted(vals[i], thr[i], side="right"))
                cc, pp_sel = sel_cols[i, :cnt], sel_pos[i, :cnt]
                real = cc < nvalid
                cc, pp_sel = cc[real], pp_sel[real]
                finish(i, qi, pp_sel[np.argsort(cc)].astype(np.int64))
            continue

        # --- host-side stable top-k over the pulled padded block -----------
        dmat = np.asarray(dmat)
        host_block_bytes += dmat.nbytes
        dmat = dmat[:qb]
        safe_pos = np.clip(cand_pos, 0, max(0, u_pad - 1))
        d_blk = np.where(
            cand_pos >= 0,
            np.take_along_axis(dmat, safe_pos, axis=1),
            np.inf,
        ).astype(np.float32)
        order = np.argsort(d_blk, axis=1, kind="stable")
        for i in range(qb):
            qi = q0 + i
            nvalid = int(cand_lens[i])
            take = min(topk + RESCORE_SLACK, nvalid)
            if take == 0:
                continue
            # kernel distances only have to get the top-k *set* right.  The
            # expanded qn-2qc+cn form cancels catastrophically for
            # near-duplicate vectors, so candidates near the shortlist
            # boundary may be mis-ranked by up to the cancellation error —
            # extend the shortlist through that error band so the exact
            # re-score below sees every potential top-k member.
            row = d_blk[i]
            bound = float(row[order[i, take - 1]])
            eps = rescore_eps(index.d, bound,
                              0.0 if use_pq else float(qn_host[i]))
            while take < nvalid and row[order[i, take]] <= bound + eps:
                take += 1
            # candidate *row positions* are the oracle's concat positions:
            # sorting them restores the oracle's stable tie order.
            sel = np.sort(order[i, :take])
            finish(i, qi, cand_pos[i, sel])

    # --- late id resolution: one pass over every winning pair --------------
    t_res = time.perf_counter()
    if res_q:
        rq = np.concatenate(res_q)
        rs = np.concatenate(res_slot)
        ids = resolve_ids_batch(
            index, np.concatenate(res_cluster), np.concatenate(res_offset))
        all_ids[rq, rs] = ids
        if with_keys:
            all_keys[rq, rs] = np.concatenate(res_key)
    resolve_s = time.perf_counter() - t_res
    index._last_resolve_s = resolve_s

    stats = SearchStats(
        wall_s=time.perf_counter() - t0,
        ndis=ndis,
        id_resolve_s=resolve_s,
        decodes=index.decoded_cache.decodes - decodes_before,
        distinct_probed=len(distinct),
        batches=nbatches,
        engine=engine,
        host_block_bytes=host_block_bytes,
        device_select=n_dev_select,
        merge_keys=all_keys,
    )
    return all_ids, all_d, stats


# ---------------------------------------------------------------------------
# batched flat (brute-force) search
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _flat_select_runner():
    """Jitted score + segmented top-k for the flat path, fused on device."""
    jax, jnp = _jax(), _jax().numpy

    @functools.partial(
        jax.jit, static_argnames=("k", "engine", "interpret", "nvalid"))
    def run(qblk, base, k, engine, interpret, nvalid):
        from ..kernels.seg_topk import seg_topk, seg_topk_xla

        if engine == "pallas":
            from ..kernels.l2_topk import l2_dist

            dmat = l2_dist(qblk, base, interpret=interpret)
        else:
            qn = jnp.sum(qblk * qblk, axis=1, keepdims=True)
            bn = jnp.sum(base * base, axis=1)
            dmat = qn - 2.0 * qblk @ base.T + bn[None]
        lens = jnp.full(qblk.shape[0], nvalid, jnp.int32)
        if engine == "pallas":
            return seg_topk(dmat, lens, k, interpret=interpret)
        return seg_topk_xla(dmat, lens, k)

    return run


def batched_flat_search(vecs: np.ndarray, queries: np.ndarray,
                        topk: int = 10, engine: str = "auto",
                        query_block: int = DEFAULT_QUERY_BLOCK):
    """Kernel-scored brute-force search; bit-identical to the numpy loop.

    Scores each query block against the whole base through the same
    engines the IVF path uses (``l2_dist`` Pallas kernel or plain XLA),
    cuts the short-list with the device-side segmented top-k
    (``repro.kernels.seg_topk``) so only ``(qb, K)`` shortlists ever
    reach the host, and re-scores the short-list with the oracle's numpy
    scalar path (``score_rows_flat`` + ``select_topk``) — so ids **and**
    distances match ``np.argsort(score_rows_flat(...))`` exactly, ties
    to the lower row, for either engine.

    Returns ``(ids (nq, topk) int64, dists (nq, topk) f32, SearchStats)``
    with ``engine="flat-pallas"`` / ``"flat-xla"``.
    """
    from .stats import SearchStats

    jnp = _jax().numpy
    engine = _resolve_engine(engine)
    interpret = _jax().default_backend() == "cpu"
    t0 = time.perf_counter()
    vecs = np.ascontiguousarray(np.asarray(vecs, np.float32))
    queries = np.asarray(queries, np.float32)
    nq, d = queries.shape
    n = vecs.shape[0]
    topk_eff = min(topk, n)
    all_ids = np.zeros((nq, topk), np.int64)
    all_d = np.full((nq, topk), np.inf, np.float32)
    runner = _flat_select_runner()
    n_pad = _bucket(max(n, 1))
    base = np.zeros((n_pad, d), np.float32)
    base[:n] = vecs
    base_dev = jnp.asarray(base)
    nbatches = 0
    host_block_bytes = 0
    n_dev_select = 0
    for q0 in range(0, nq, query_block):
        q1 = min(nq, q0 + query_block)
        qb = q1 - q0
        nbatches += 1
        n_dev_select += 1
        qb_pad = _bucket(qb, floor=8)
        qblk = np.zeros((qb_pad, d), np.float32)
        qblk[:qb] = queries[q0:q1]
        qblk_dev = jnp.asarray(qblk)
        qn_host = np.einsum("qd,qd->q", qblk[:qb], qblk[:qb])
        K = min(_bucket(min(topk_eff + RESCORE_SLACK, n), floor=16), n_pad)
        while True:
            vals_d, cols_d = runner(qblk_dev, base_dev, k=K, engine=engine,
                                    interpret=interpret, nvalid=n)
            vals = np.asarray(vals_d)
            cols = np.asarray(cols_d)
            host_block_bytes += vals.nbytes + cols.nbytes
            vals = vals[:qb]
            thr = np.full(qb, -np.inf)
            retry = False
            for i in range(qb):
                take = min(topk_eff + RESCORE_SLACK, n)
                if take == 0:
                    continue
                bound = float(vals[i, take - 1])
                eps = rescore_eps(d, bound, float(qn_host[i]))
                thr[i] = bound + eps
                if n > K and vals[i, K - 1] <= thr[i]:
                    retry = True        # band may extend past the K cut
            if not retry or K >= n_pad:
                break
            K = min(2 * K, n_pad)
        for i in range(qb):
            qi = q0 + i
            if n == 0:
                continue
            cnt = int(np.searchsorted(vals[i], thr[i], side="right"))
            rows = cols[i, :cnt]
            rows = np.sort(rows[rows < n]).astype(np.int64)
            d_exact = score_rows_flat(vecs[rows], queries[qi])
            best = select_topk(d_exact, topk)
            n_found = best.shape[0]
            all_ids[qi, :n_found] = rows[best]
            all_d[qi, :n_found] = d_exact[best]

    stats = SearchStats(
        wall_s=time.perf_counter() - t0,
        ndis=n * nq,
        id_resolve_s=0.0,
        batches=nbatches,
        engine=f"flat-{engine}",
        host_block_bytes=host_block_bytes,
        device_select=n_dev_select,
    )
    return all_ids, all_d, stats
