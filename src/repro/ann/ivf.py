"""IVF index with pluggable id/code compression — the paper's main testbed.

Build: k-means coarse quantizer (K clusters), vectors stored per cluster
(flat f32 or PQ codes, PQ codes optionally Pólya-coded per Eq. 6-7), ids
stored through any ``repro.core.codecs`` codec (paper's online setting:
one stream per cluster) or jointly through a wavelet tree (full random
access, §4.1).

Search implements the paper's late-id-resolution trick: the scanner keeps
``(cluster, offset)`` pairs in the top-k structure and resolves actual ids
only for the final results — per-cluster decode (ROC/gap), random access
(EF/compact), or ``select`` (WT).

``search`` is the batched engine (repro.ann.scan): cluster-deduplicated
blocked scanning through the Pallas kernels with one id-resolution pass
per call.  ``search_ref`` keeps the original per-query/per-probe Python
loop as the bit-exact test oracle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from ..core.codecs import get_codec
from ..core.polya import PolyaCodec
from ..core.wavelet_tree import WaveletTree
from .kmeans import assign, kmeans
from .pq import ProductQuantizer
from .scan import (DecodedListCache, batched_search, coarse_probes,
                   resolve_ids_batch, score_rows_flat, select_topk)
from .stats import SearchStats

__all__ = ["IVFIndex", "SearchStats"]


@dataclasses.dataclass
class IVFIndex:
    nlist: int
    id_codec: str = "roc"
    pq: Optional[ProductQuantizer] = None
    code_codec: Optional[str] = None     # None | "polya"
    cache_bytes: Optional[int] = None    # DecodedListCache budget (None = default)

    def build(self, x: np.ndarray, seed: int = 0,
              centroids: Optional[np.ndarray] = None) -> "IVFIndex":
        self.n, self.d = x.shape
        self.centroids = (centroids if centroids is not None
                          else kmeans(x, self.nlist, iters=8, seed=seed))
        assign_ = assign(x, self.centroids)
        order = np.argsort(assign_, kind="stable")
        self.cluster_of = assign_
        sizes = np.bincount(assign_, minlength=self.nlist)
        self.offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        self.sizes = sizes
        ids_sorted = order.astype(np.int64)
        self._lists = [
            ids_sorted[self.offsets[k]: self.offsets[k + 1]]
            for k in range(self.nlist)
        ]
        # --- vectors / codes, cluster-grouped ---------------------------------
        if self.pq is not None:
            if self.pq.codebooks is None:
                self.pq.train(x)
            codes = self.pq.encode(x)
            self.codes = codes[order]          # grouped by cluster
            self.vecs = None
        else:
            self.codes = None
            self.vecs = x[order].astype(np.float32)
        # --- id compression -----------------------------------------------------
        if self.id_codec == "wt":
            self._wt = WaveletTree.build(assign_, self.nlist, compressed=False)
            self._blobs = None
        elif self.id_codec == "wt1":
            self._wt = WaveletTree.build(assign_, self.nlist, compressed=True)
            self._blobs = None
        else:
            self._wt = None
            codec = get_codec(self.id_codec)
            self._codec = codec
            self._blobs = [
                codec.encode(np.sort(lst), self.n) for lst in self._lists
            ]
        # --- optional code compression ------------------------------------------
        if self.code_codec == "polya" and self.codes is not None:
            pc = PolyaCodec()
            per_cluster = [
                self.codes[self.offsets[k]: self.offsets[k + 1]]
                for k in range(self.nlist)
            ]
            self._code_blob = pc.encode([c for c in per_cluster])
            self._polya = pc
        else:
            self._code_blob = None
        self._decoded_cache = self._new_cache()
        return self

    def _new_cache(self) -> DecodedListCache:
        if self.cache_bytes is not None:
            return DecodedListCache(max_bytes=self.cache_bytes)
        return DecodedListCache()

    @property
    def decoded_cache(self) -> DecodedListCache:
        # lazily attached so indexes built before this field existed
        # (e.g. unpickled) still work
        if not hasattr(self, "_decoded_cache"):
            self._decoded_cache = self._new_cache()
        return self._decoded_cache

    def add(self, x: np.ndarray) -> "IVFIndex":
        """Append new vectors to a built index (ids ``n .. n+len(x)-1``).

        New ids are larger than every existing id, so appending each one to
        the tail of its cluster's list keeps storage order == sorted order
        (the invariant ``resolve_ids`` relies on).  Touched clusters are
        re-encoded; the wavelet tree / Pólya blob are rebuilt (they are
        joint structures over all clusters).
        """
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None]
        m = x.shape[0]
        if m == 0:
            return self
        assign_new = assign(x, self.centroids)
        new_ids = np.arange(self.n, self.n + m, dtype=np.int64)
        new_codes = self.pq.encode(x) if self.pq is not None else None
        # regroup per-cluster storage with the new rows appended in id order
        new_lists: List[np.ndarray] = []
        vec_parts: List[np.ndarray] = []
        for k in range(self.nlist):
            sel = assign_new == k
            new_lists.append(np.concatenate([self._lists[k], new_ids[sel]]))
            lo, hi = self.offsets[k], self.offsets[k + 1]
            if self.pq is not None:
                vec_parts.append(self.codes[lo:hi])
                if sel.any():
                    vec_parts.append(new_codes[sel])
            else:
                vec_parts.append(self.vecs[lo:hi])
                if sel.any():
                    vec_parts.append(x[sel])
        self._lists = new_lists
        self.sizes = self.sizes + np.bincount(assign_new, minlength=self.nlist)
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).astype(np.int64)
        if self.pq is not None:
            self.codes = np.concatenate(vec_parts, axis=0)
        else:
            self.vecs = np.concatenate(vec_parts, axis=0)
        self.cluster_of = np.concatenate([self.cluster_of, assign_new])
        self.n += m
        # id structures: joint ones rebuild, per-cluster ones re-encode.
        # The universe grew from n-m to n, so *every* stream blob must be
        # re-encoded (codec rates and decode both depend on the universe).
        if self._wt is not None:
            self._wt = WaveletTree.build(self.cluster_of, self.nlist,
                                         compressed=(self.id_codec == "wt1"))
        else:
            self._blobs = [self._codec.encode(lst, self.n)
                           for lst in self._lists]
        if self._code_blob is not None:
            per_cluster = [self.codes[self.offsets[k]: self.offsets[k + 1]]
                           for k in range(self.nlist)]
            self._code_blob = self._polya.encode(per_cluster)
        self.decoded_cache.clear()
        return self

    # -- sizes -------------------------------------------------------------------
    def id_bits(self) -> int:
        if self._wt is not None:
            return self._wt.size_bits
        return int(sum(self._codec.size_bits(b) for b in self._blobs))

    def bits_per_id(self) -> float:
        return self.id_bits() / self.n

    def code_bits_per_element(self) -> float:
        if self._code_blob is None:
            return 8.0
        return self._polya.bits_per_element(self._code_blob)

    # -- id resolution (the §4.1 trick) --------------------------------------------
    def resolve_ids(self, clusters: np.ndarray, offsets: np.ndarray) -> np.ndarray:
        """(cluster, offset) pairs -> database ids, decoding lazily.

        Note: lists were encoded SORTED; the scanner's offsets refer to
        storage order, so build/searching keeps storage order == sorted
        order (ids within a cluster are sorted by construction here).
        Grouped one-pass resolution; stream codecs decode each distinct
        cluster at most once per call through the index's LRU cache.
        """
        t0 = time.perf_counter()
        out = resolve_ids_batch(self, clusters, offsets)
        self._last_resolve_s = time.perf_counter() - t0
        return out

    # -- search ---------------------------------------------------------------------
    def search(self, queries: np.ndarray, nprobe: int = 16, topk: int = 10,
               engine: str = "auto", query_block: int = 64,
               with_keys: bool = False):
        """Batched search (repro.ann.scan). Returns (ids, dists, SearchStats).

        Bit-identical to :meth:`search_ref`; ``engine`` picks the scoring
        backend ("pallas" kernels, "xla", or "auto" = pallas off-CPU).
        ``with_keys`` fills ``stats.merge_keys`` with the stable tie-order
        keys the sharded router merges by (see ``batched_search``).
        """
        return batched_search(self, queries, nprobe=nprobe, topk=topk,
                              engine=engine, query_block=query_block,
                              with_keys=with_keys)

    def search_ref(self, queries: np.ndarray, nprobe: int = 16,
                   topk: int = 10):
        """Reference per-query/per-probe scan — the batched engine's oracle.

        Deterministic by construction: shared coarse probe, stable top-k
        (ties to the earlier candidate in probe order), scalar numpy
        scoring.  O(nq * nprobe) Python overhead — test/debug use only.
        """
        t0 = time.perf_counter()
        nq = queries.shape[0]
        probes = coarse_probes(queries, self.centroids, nprobe)
        tables = self.pq.adc_tables(queries) if self.pq is not None else None
        all_ids = np.zeros((nq, topk), np.int64)
        all_d = np.full((nq, topk), np.inf, np.float32)
        ndis = 0
        res_s = 0.0
        distinct: set = set()
        decodes0 = self.decoded_cache.decodes
        for qi in range(nq):
            cand_d: List[np.ndarray] = []
            cand_k: List[np.ndarray] = []
            cand_o: List[np.ndarray] = []
            for k in probes[qi]:
                lo, hi = self.offsets[k], self.offsets[k + 1]
                if hi == lo:
                    continue
                distinct.add(int(k))
                if self.pq is not None:
                    d = ProductQuantizer.adc_score(self.codes[lo:hi], tables[qi])
                else:
                    d = score_rows_flat(self.vecs[lo:hi], queries[qi])
                ndis += hi - lo
                cand_d.append(d)
                cand_k.append(np.full(hi - lo, k, np.int32))
                cand_o.append(np.arange(hi - lo, dtype=np.int32))
            if not cand_d:
                continue
            d = np.concatenate(cand_d)
            kk = np.concatenate(cand_k)
            oo = np.concatenate(cand_o)
            sel = select_topk(d, topk)
            # late id resolution (paper §4.1)
            ids = self.resolve_ids(kk[sel], oo[sel])
            res_s += self._last_resolve_s
            n_found = len(sel)
            all_ids[qi, :n_found] = ids
            all_d[qi, :n_found] = d[sel]
        wall = time.perf_counter() - t0
        return all_ids, all_d, SearchStats(
            wall_s=wall, ndis=ndis, id_resolve_s=res_s,
            decodes=self.decoded_cache.decodes - decodes0,
            distinct_probed=len(distinct), batches=0, engine="ref")
