"""IVF index with pluggable id/code compression — the paper's main testbed.

Build: k-means coarse quantizer (K clusters), vectors stored per cluster
(flat f32 or PQ codes, PQ codes optionally Pólya-coded per Eq. 6-7), ids
stored through any ``repro.core.codecs`` codec (paper's online setting:
one stream per cluster) or jointly through a wavelet tree (full random
access, §4.1).

Id (and Pólya code) storage is **epoched** (:class:`repro.core.epoch.
EpochStore`): ``build`` seals one epoch over ``[0, n)``; each ``add``
seals a new epoch over just the appended rows, so ingest entropy-codes
O(Δ) data instead of re-encoding the whole index, and ``compact`` folds
the epochs back into one blob to recover single-universe rates.  The
scanner is oblivious — per-cluster storage stays globally grouped
(offsets/sizes/arena gathers unchanged) and the concatenated per-epoch
lists are globally sorted, so only ``resolve_ids`` routes through epochs.

Search implements the paper's late-id-resolution trick: the scanner keeps
``(cluster, offset)`` pairs in the top-k structure and resolves actual ids
only for the final results — per-cluster decode (ROC/gap), random access
(EF/compact), or ``select`` (WT).

``search`` is the batched engine (repro.ann.scan): cluster-deduplicated
blocked scanning through the Pallas kernels with one id-resolution pass
per call.  ``search_ref`` keeps the original per-query/per-probe Python
loop as the bit-exact test oracle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from ..core.epoch import EpochStore
from ..core.polya import PolyaCodec
from .kmeans import assign, kmeans
from .pq import ProductQuantizer
from .scan import (CacheOwnerMixin, batched_search, coarse_probes,
                   resolve_ids_batch, score_rows_flat, select_topk)
from .stats import SearchStats

__all__ = ["IVFIndex", "SearchStats"]


@dataclasses.dataclass
class IVFIndex(CacheOwnerMixin):
    nlist: int
    id_codec: str = "roc"
    pq: Optional[ProductQuantizer] = None
    code_codec: Optional[str] = None     # None | "polya"
    cache_bytes: Optional[int] = None    # DecodedListCache budget (None = default)
    cache_policy: str = "lru"            # "lru" | "2q"
    max_epochs: Optional[int] = None     # auto-compact past this epoch count

    def build(self, x: np.ndarray, seed: int = 0,
              centroids: Optional[np.ndarray] = None) -> "IVFIndex":
        self.n, self.d = x.shape
        self.centroids = (centroids if centroids is not None
                          else kmeans(x, self.nlist, iters=8, seed=seed))
        assign_ = assign(x, self.centroids)
        order = np.argsort(assign_, kind="stable")
        self.cluster_of = assign_
        sizes = np.bincount(assign_, minlength=self.nlist)
        self.offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        self.sizes = sizes
        ids_sorted = order.astype(np.int64)
        self._lists = [
            ids_sorted[self.offsets[k]: self.offsets[k + 1]]
            for k in range(self.nlist)
        ]
        # --- vectors / codes, cluster-grouped ---------------------------------
        if self.pq is not None:
            if self.pq.codebooks is None:
                self.pq.train(x)
            codes = self.pq.encode(x)
            self.codes = codes[order]          # grouped by cluster
            self.vecs = None
        else:
            self.codes = None
            self.vecs = x[order].astype(np.float32)
        # --- id compression: one epoch over [0, n) ------------------------------
        self._ids = EpochStore(self.nlist, self.id_codec)
        self._ids.append(self._lists, 0, self.n)
        # --- optional code compression ------------------------------------------
        if self.code_codec == "polya" and self.codes is not None:
            self._polya = PolyaCodec()
            per_cluster = [
                self.codes[self.offsets[k]: self.offsets[k + 1]]
                for k in range(self.nlist)
            ]
            self._code_blobs = [self._polya.encode(per_cluster)]
        else:
            self._code_blobs = None
        self._decoded_cache = self._new_cache()
        return self

    # -- online ingest (epoch scheme) ---------------------------------------------
    def add(self, x: np.ndarray) -> "IVFIndex":
        """Append new vectors to a built index (ids ``n .. n+len(x)-1``).

        Seals one new epoch over exactly the appended rows: only Δ ids
        (and Δ PQ codes) are entropy-coded — existing epoch blobs, wavelet
        trees and warm cache entries are untouched.  New ids are larger
        than every existing id, so appending to each cluster's tail keeps
        storage order == sorted order (the invariant ``resolve_ids``
        relies on) across epochs.
        """
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None]
        m = x.shape[0]
        if m == 0:
            return self
        self.append_epoch(x, np.arange(self.n, self.n + m, dtype=np.int64), m)
        return self

    def append_epoch(self, x_new: np.ndarray, new_ids: np.ndarray,
                     count: int) -> "IVFIndex":
        """Seal the epoch ``[n, n + count)`` holding the given rows.

        Monolithically ``add`` passes every new row; a cluster shard
        passes only the rows whose cluster it owns but the *global*
        ``count``, so epoch boundaries (and therefore every owned blob's
        relative universe) stay identical across shards — the byte-parity
        the sharded merge relies on.  ``new_ids`` must be strictly
        ascending global ids inside the epoch range.
        """
        base = self.n
        x_new = np.asarray(x_new, np.float32).reshape(-1, self.d)
        new_ids = np.asarray(new_ids, np.int64)
        if x_new.shape[0] != new_ids.shape[0]:
            raise ValueError("one id per appended row")
        if new_ids.size and (
                int(new_ids[0]) < base
                or int(new_ids[-1]) >= base + count
                or np.any(np.diff(new_ids) <= 0)):
            raise ValueError(
                f"epoch ids must be strictly ascending within "
                f"[{base}, {base + count})")
        if new_ids.size:
            assign_new = assign(x_new, self.centroids)
            new_codes = self.pq.encode(x_new) if self.pq is not None else None
        else:
            assign_new = np.zeros(0, np.int64)
            new_codes = None
        # regroup per-cluster storage with the new rows appended in id order
        # (O(n) memcpy — cheap next to entropy coding, and it keeps the
        # batched scanner's offsets/sizes/arena layout unchanged)
        rel_lists: List[np.ndarray] = []
        epoch_codes: List[np.ndarray] = []
        vec_parts: List[np.ndarray] = []
        for k in range(self.nlist):
            sel = assign_new == k
            rel_lists.append(new_ids[sel] - base)
            self._lists[k] = np.concatenate([self._lists[k], new_ids[sel]])
            lo, hi = self.offsets[k], self.offsets[k + 1]
            if self.pq is not None:
                vec_parts.append(self.codes[lo:hi])
                if sel.any():
                    vec_parts.append(new_codes[sel])
                epoch_codes.append(
                    new_codes[sel] if new_codes is not None
                    else np.zeros((0, self.pq.m), np.uint8))
            else:
                vec_parts.append(self.vecs[lo:hi])
                if sel.any():
                    vec_parts.append(x_new[sel])
        self.sizes = self.sizes + np.bincount(assign_new, minlength=self.nlist)
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).astype(np.int64)
        if self.pq is not None:
            self.codes = np.concatenate(vec_parts, axis=0)
        else:
            self.vecs = np.concatenate(vec_parts, axis=0)
        # cluster_of stays universe-sized; only locally-held rows are known
        # (a shard leaves its unowned slots at 0, same as the RIDX loader)
        ext = np.zeros(count, np.int64)
        ext[new_ids - base] = assign_new
        self.cluster_of = np.concatenate(
            [np.asarray(self.cluster_of, np.int64), ext])
        self._ids.append(rel_lists, base, count)
        if self._code_blobs is not None:
            self._code_blobs.append(self._polya.encode(epoch_codes))
        self.n = base + count
        # appends never alias warm (epoch, cluster) cache keys, so no cache
        # invalidation here; compaction renumbers epochs and must clear
        if self.max_epochs is not None and self._ids.n_epochs > self.max_epochs:
            self.compact()
        return self

    @property
    def n_epochs(self) -> int:
        return self._ids.n_epochs

    def compact(self) -> "IVFIndex":
        """Fold every epoch into one ``[0, n)`` blob set.

        Re-encodes all ids (and Pólya codes) against the single global
        universe — the paper's compression rates again, at O(n) cost.
        Run it off the ingest path (``max_epochs`` threshold, or a
        service's background tick) to bound the epoch bpv overhead.
        """
        self._ids.compact(self._lists, self.n)
        if self._code_blobs is not None:
            per_cluster = [self.codes[self.offsets[k]: self.offsets[k + 1]]
                           for k in range(self.nlist)]
            self._code_blobs = [self._polya.encode(per_cluster)]
        # epoch indices restarted at 0: stale (epoch, cluster) keys would alias
        self.decoded_cache.clear()
        return self

    # -- sizes -------------------------------------------------------------------
    def id_bits(self) -> int:
        return self._ids.id_bits()

    def bits_per_id(self) -> float:
        return self.id_bits() / self.n

    def code_bits_per_element(self) -> float:
        if self._code_blobs is None:
            return 8.0
        bits = sum(int(b["bits"]) for b in self._code_blobs)
        elems = sum(int(sum(b["sizes"])) * int(b["m"])
                    for b in self._code_blobs)
        return bits / max(1, elems)

    @property
    def _code_blob(self):
        # legacy single-blob view (v1 RIVF container): exact for one epoch,
        # re-encoded from the global grouping otherwise
        if self._code_blobs is None:
            return None
        if len(self._code_blobs) == 1:
            return self._code_blobs[0]
        per_cluster = [self.codes[self.offsets[k]: self.offsets[k + 1]]
                       for k in range(self.nlist)]
        return self._polya.encode(per_cluster)

    # -- id resolution (the §4.1 trick) --------------------------------------------
    def resolve_ids(self, clusters: np.ndarray, offsets: np.ndarray) -> np.ndarray:
        """(cluster, offset) pairs -> database ids, decoding lazily.

        Note: lists were encoded SORTED; the scanner's offsets refer to
        storage order, so build/searching keeps storage order == sorted
        order (ids within a cluster are sorted by construction here, and
        epoch concatenation preserves it).  Grouped one-pass resolution;
        stream codecs decode each distinct (epoch, cluster) at most once
        per call through the index's cache.
        """
        t0 = time.perf_counter()
        out = resolve_ids_batch(self, clusters, offsets)
        self._last_resolve_s = time.perf_counter() - t0
        return out

    # -- search ---------------------------------------------------------------------
    def search(self, queries: np.ndarray, nprobe: int = 16, topk: int = 10,
               engine: str = "auto", query_block: int = 64,
               with_keys: bool = False, select: str = "auto",
               select_min: int | None = None):
        """Batched search (repro.ann.scan). Returns (ids, dists, SearchStats).

        Bit-identical to :meth:`search_ref`; ``engine`` picks the scoring
        backend ("pallas" kernels, "xla", or "auto" = pallas off-CPU);
        ``select`` places the top-k cut host- or device-side (segmented
        top-k, ``repro.kernels.seg_topk``) — results are identical either
        way, only ``stats.host_block_bytes``/``stats.device_select``
        change.  ``with_keys`` fills ``stats.merge_keys`` with the stable
        tie-order keys the sharded router merges by (``batched_search``).
        """
        return batched_search(self, queries, nprobe=nprobe, topk=topk,
                              engine=engine, query_block=query_block,
                              with_keys=with_keys, select=select,
                              select_min=select_min)

    def search_ref(self, queries: np.ndarray, nprobe: int = 16,
                   topk: int = 10):
        """Reference per-query/per-probe scan — the batched engine's oracle.

        Deterministic by construction: shared coarse probe, stable top-k
        (ties to the earlier candidate in probe order), scalar numpy
        scoring.  O(nq * nprobe) Python overhead — test/debug use only.
        """
        t0 = time.perf_counter()
        nq = queries.shape[0]
        probes = coarse_probes(queries, self.centroids, nprobe)
        tables = self.pq.adc_tables(queries) if self.pq is not None else None
        all_ids = np.zeros((nq, topk), np.int64)
        all_d = np.full((nq, topk), np.inf, np.float32)
        ndis = 0
        res_s = 0.0
        distinct: set = set()
        decodes0 = self.decoded_cache.decodes
        for qi in range(nq):
            cand_d: List[np.ndarray] = []
            cand_k: List[np.ndarray] = []
            cand_o: List[np.ndarray] = []
            for k in probes[qi]:
                lo, hi = self.offsets[k], self.offsets[k + 1]
                if hi == lo:
                    continue
                distinct.add(int(k))
                if self.pq is not None:
                    d = ProductQuantizer.adc_score(self.codes[lo:hi], tables[qi])
                else:
                    d = score_rows_flat(self.vecs[lo:hi], queries[qi])
                ndis += hi - lo
                cand_d.append(d)
                cand_k.append(np.full(hi - lo, k, np.int32))
                cand_o.append(np.arange(hi - lo, dtype=np.int32))
            if not cand_d:
                continue
            d = np.concatenate(cand_d)
            kk = np.concatenate(cand_k)
            oo = np.concatenate(cand_o)
            sel = select_topk(d, topk)
            # late id resolution (paper §4.1)
            ids = self.resolve_ids(kk[sel], oo[sel])
            res_s += self._last_resolve_s
            n_found = len(sel)
            all_ids[qi, :n_found] = ids
            all_d[qi, :n_found] = d[sel]
        wall = time.perf_counter() - t0
        return all_ids, all_d, SearchStats(
            wall_s=wall, ndis=ndis, id_resolve_s=res_s,
            decodes=self.decoded_cache.decodes - decodes0,
            distinct_probed=len(distinct), batches=0, engine="ref")
