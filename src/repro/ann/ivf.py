"""IVF index with pluggable id/code compression — the paper's main testbed.

Build: k-means coarse quantizer (K clusters), vectors stored per cluster
(flat f32 or PQ codes, PQ codes optionally Pólya-coded per Eq. 6-7), ids
stored through any ``repro.core.codecs`` codec (paper's online setting:
one stream per cluster) or jointly through a wavelet tree (full random
access, §4.1).

Search implements the paper's late-id-resolution trick: the scanner keeps
``(cluster, offset)`` pairs in the top-k structure and resolves actual ids
only for the final results — per-cluster decode (ROC/gap), random access
(EF/compact), or ``select`` (WT).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.codecs import get_codec
from ..core.polya import PolyaCodec
from ..core.wavelet_tree import WaveletTree
from .kmeans import assign, kmeans
from .pq import ProductQuantizer

__all__ = ["IVFIndex", "SearchStats"]


@dataclasses.dataclass
class SearchStats:
    wall_s: float
    ndis: int
    id_resolve_s: float


@dataclasses.dataclass
class IVFIndex:
    nlist: int
    id_codec: str = "roc"
    pq: Optional[ProductQuantizer] = None
    code_codec: Optional[str] = None     # None | "polya"

    def build(self, x: np.ndarray, seed: int = 0,
              centroids: Optional[np.ndarray] = None) -> "IVFIndex":
        self.n, self.d = x.shape
        self.centroids = (centroids if centroids is not None
                          else kmeans(x, self.nlist, iters=8, seed=seed))
        assign_ = assign(x, self.centroids)
        order = np.argsort(assign_, kind="stable")
        self.cluster_of = assign_
        sizes = np.bincount(assign_, minlength=self.nlist)
        self.offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        self.sizes = sizes
        ids_sorted = order.astype(np.int64)
        self._lists = [
            ids_sorted[self.offsets[k]: self.offsets[k + 1]]
            for k in range(self.nlist)
        ]
        # --- vectors / codes, cluster-grouped ---------------------------------
        if self.pq is not None:
            if self.pq.codebooks is None:
                self.pq.train(x)
            codes = self.pq.encode(x)
            self.codes = codes[order]          # grouped by cluster
            self.vecs = None
        else:
            self.codes = None
            self.vecs = x[order].astype(np.float32)
        # --- id compression -----------------------------------------------------
        if self.id_codec == "wt":
            self._wt = WaveletTree.build(assign_, self.nlist, compressed=False)
            self._blobs = None
        elif self.id_codec == "wt1":
            self._wt = WaveletTree.build(assign_, self.nlist, compressed=True)
            self._blobs = None
        else:
            self._wt = None
            codec = get_codec(self.id_codec)
            self._codec = codec
            self._blobs = [
                codec.encode(np.sort(lst), self.n) for lst in self._lists
            ]
        # --- optional code compression ------------------------------------------
        if self.code_codec == "polya" and self.codes is not None:
            pc = PolyaCodec()
            per_cluster = [
                self.codes[self.offsets[k]: self.offsets[k + 1]]
                for k in range(self.nlist)
            ]
            self._code_blob = pc.encode([c for c in per_cluster])
            self._polya = pc
        else:
            self._code_blob = None
        return self

    # -- sizes -------------------------------------------------------------------
    def id_bits(self) -> int:
        if self._wt is not None:
            return self._wt.size_bits
        return int(sum(self._codec.size_bits(b) for b in self._blobs))

    def bits_per_id(self) -> float:
        return self.id_bits() / self.n

    def code_bits_per_element(self) -> float:
        if self._code_blob is None:
            return 8.0
        return self._polya.bits_per_element(self._code_blob)

    # -- id resolution (the §4.1 trick) --------------------------------------------
    def resolve_ids(self, clusters: np.ndarray, offsets: np.ndarray) -> np.ndarray:
        """(cluster, offset) pairs -> database ids, decoding lazily."""
        t0 = time.perf_counter()
        out = np.zeros(len(clusters), np.int64)
        if self._wt is not None:
            for i, (k, o) in enumerate(zip(clusters, offsets)):
                out[i] = self._wt.select(int(k), int(o))
        else:
            # note: lists were encoded SORTED; the scanner's offsets refer to
            # storage order, so build/searching keeps storage order == sorted
            # order (ids within a cluster are sorted by construction here).
            cache: Dict[int, np.ndarray] = {}
            for i, (k, o) in enumerate(zip(clusters, offsets)):
                k = int(k)
                if hasattr(self._blobs[k], "access"):
                    out[i] = self._blobs[k].access(int(o))
                    continue
                if k not in cache:
                    cache[k] = np.asarray(
                        self._codec.decode(self._blobs[k], self.n))
                out[i] = cache[k][int(o)]
        self._last_resolve_s = time.perf_counter() - t0
        return out

    # -- search ---------------------------------------------------------------------
    def search(self, queries: np.ndarray, nprobe: int = 16, topk: int = 10):
        """Returns (ids (nq, topk), dists, SearchStats)."""
        t0 = time.perf_counter()
        nq = queries.shape[0]
        qc = (
            np.sum(queries**2, 1, keepdims=True)
            - 2.0 * queries @ self.centroids.T
            + np.sum(self.centroids**2, 1)[None]
        )
        probes = np.argsort(qc, axis=1)[:, :nprobe]
        tables = self.pq.adc_tables(queries) if self.pq is not None else None
        all_ids = np.zeros((nq, topk), np.int64)
        all_d = np.full((nq, topk), np.inf, np.float32)
        ndis = 0
        res_s = 0.0
        for qi in range(nq):
            cand_d: List[np.ndarray] = []
            cand_k: List[np.ndarray] = []
            cand_o: List[np.ndarray] = []
            for k in probes[qi]:
                lo, hi = self.offsets[k], self.offsets[k + 1]
                if hi == lo:
                    continue
                if self.pq is not None:
                    d = ProductQuantizer.adc_score(self.codes[lo:hi], tables[qi])
                else:
                    diff = self.vecs[lo:hi] - queries[qi][None]
                    d = np.einsum("nd,nd->n", diff, diff)
                ndis += hi - lo
                cand_d.append(d)
                cand_k.append(np.full(hi - lo, k, np.int32))
                cand_o.append(np.arange(hi - lo, dtype=np.int32))
            d = np.concatenate(cand_d)
            kk = np.concatenate(cand_k)
            oo = np.concatenate(cand_o)
            sel = np.argpartition(d, min(topk, len(d) - 1))[:topk]
            sel = sel[np.argsort(d[sel])]
            # late id resolution (paper §4.1)
            ids = self.resolve_ids(kk[sel], oo[sel])
            res_s += self._last_resolve_s
            n_found = len(sel)
            all_ids[qi, :n_found] = ids
            all_d[qi, :n_found] = d[sel]
        wall = time.perf_counter() - t0
        return all_ids, all_d, SearchStats(wall_s=wall, ndis=ndis, id_resolve_s=res_s)
