"""Lloyd k-means in JAX (used for IVF coarse quantizers and PQ codebooks).

Chunked distance computation keeps memory bounded at (chunk x k); the
assignment step is the same compute pattern the Pallas ``l2_topk`` kernel
accelerates on TPU (argmin = top-1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["kmeans", "assign"]


@functools.partial(jax.jit, static_argnames=("chunk",))
def _assign_jit(x, centroids, chunk: int = 8192):
    n = x.shape[0]
    chunk = min(chunk, n)

    def body(i, acc):
        sl = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 0)
        d = (
            jnp.sum(sl * sl, 1, keepdims=True)
            - 2.0 * sl @ centroids.T
            + jnp.sum(centroids * centroids, 1)[None]
        )
        a = jnp.argmin(d, 1).astype(jnp.int32)
        return jax.lax.dynamic_update_slice_in_dim(acc, a, i * chunk, 0)

    steps = n // chunk
    acc = jnp.zeros((n,), jnp.int32)
    acc = jax.lax.fori_loop(0, steps, body, acc)
    rem = n - steps * chunk
    if rem:
        d = (
            jnp.sum(x[steps * chunk:] ** 2, 1, keepdims=True)
            - 2.0 * x[steps * chunk:] @ centroids.T
            + jnp.sum(centroids**2, 1)[None]
        )
        acc = acc.at[steps * chunk:].set(jnp.argmin(d, 1).astype(jnp.int32))
    return acc


def assign(x: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    return np.asarray(_assign_jit(jnp.asarray(x), jnp.asarray(centroids)))


def kmeans(x: np.ndarray, k: int, iters: int = 10, seed: int = 0) -> np.ndarray:
    """Returns (k, d) centroids trained on x (numpy in/out, JAX inside)."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    centroids = x[rng.choice(n, size=k, replace=False)].astype(np.float32)
    xj = jnp.asarray(x, jnp.float32)
    for _ in range(iters):
        a = _assign_jit(xj, jnp.asarray(centroids))
        a = np.asarray(a)
        sums = np.zeros_like(centroids)
        np.add.at(sums, a, x)
        counts = np.bincount(a, minlength=k).astype(np.float32)
        empty = counts == 0
        counts[empty] = 1.0
        centroids = sums / counts[:, None]
        if empty.any():  # re-seed empty clusters on far points
            centroids[empty] = x[rng.choice(n, size=int(empty.sum()), replace=False)]
    return centroids
