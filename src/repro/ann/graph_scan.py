"""Beam-batched graph search — lockstep best-first over whole query batches.

``GraphIndex.search_ref`` walks one query at a time with Python heaps;
fine as a correctness oracle, useless for throughput (Table 2's NSG rows
only pay off at serving time if decode cost is amortized across queries).
This module advances a *batch* of beams in lockstep, the way the IVF side
scans query blocks (``repro.ann.scan``):

1. **Lockstep pop**: every active beam pops its best frontier node for
   this step in one vectorized masked argmin over the frontier arrays
   (oracle tie order: distance, then node id).
2. **Shared frontier gather**: the popped nodes are deduped across beams
   and their friend lists decoded once — through the index's shared
   :class:`~repro.ann.scan.DecodedListCache`, so a step decodes at most
   one blob per *distinct* expanded node (and zero once the cache is
   warm).  Same-step reuse is counted as ``dedup_hits``.
3. **One blocked distance computation per step**: the union of new
   (unvisited) candidates across all beams is gathered once and scored
   against the active queries through the ``l2_dist`` Pallas kernel or
   the jitted XLA fallback (``engine=auto|xla|pallas``, resolved by
   ``scan._resolve_engine``; shapes bucketed by ``scan._bucket``).  With
   ``select="device"`` (the off-CPU ``auto`` default) the per-candidate
   distance vector is gathered on device and only a ``(n_pad,)`` vector
   crosses to the host — the ``(qb_pad, n_pad)`` step block never does
   (``stats.host_block_bytes`` / ``stats.device_select`` are the ledger).
4. **Exact beam admission**: kernel distances only *prune* — candidates
   provably outside the beam (kernel distance beyond the beam bound plus
   the shared :func:`~repro.ann.scan.rescore_eps` error band) are
   dropped; survivors are re-scored with the oracle's own numpy
   expression and admitted with the oracle's sequential heap semantics,
   evaluated in closed form (:meth:`_BeamState.admit_all`): acceptance
   reduces to a counting test and the post-step beam to one row sort —
   beams are independent, so cross-beam interleaving cannot change any
   beam's trajectory.  Returned ids AND distances are **bit-identical**
   to ``search_ref`` for every codec and engine.
5. **Array bookkeeping**: visited sets, frontiers and beams live in
   masked numpy arrays (one row per query), not Python heaps;
   :class:`SearchStats` gains ``steps`` / ``frontier_size`` /
   ``dedup_hits`` counters on top of ``visited`` / ``decodes``.

Batching contract: results are a pure function of (index, queries, ef,
topk) — independent of ``query_block``, engine choice and cache state.
Only the stats differ.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List

import numpy as np

from .scan import _bucket, _jax, _resolve_engine, rescore_eps
from .stats import SearchStats

__all__ = ["batched_graph_search"]

DEFAULT_QUERY_BLOCK = 64
# graph steps score small tiles (a few beams x a few friend lists), so the
# Pallas path uses much smaller blocks than the IVF arena scan's 256x512
GRAPH_BLOCK_Q = 64
GRAPH_BLOCK_N = 128
# wider headroom than the IVF shortlist (factor 16): beam admission has no
# slack entries to absorb a near-boundary mis-rank, so prune conservatively
PRUNE_EPS_FACTOR = 32.0

_VMAX = np.iinfo(np.int64).max


@functools.lru_cache(maxsize=None)
def _graph_scorers():
    # scorers take the device-resident base matrix plus this step's unique
    # candidate ids and gather ON DEVICE — the host uploads only the small
    # (query block, id block) tiles each step, not a full vector arena
    jax, jnp = _jax(), _jax().numpy

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def pallas(q, xdev, idx, interpret=True):
        from ..kernels.l2_topk import l2_dist

        return l2_dist(q, xdev[idx], block_q=GRAPH_BLOCK_Q,
                       block_n=GRAPH_BLOCK_N, interpret=interpret)

    @jax.jit
    def xla(q, xdev, idx):
        a = xdev[idx]
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        an = jnp.sum(a * a, axis=1)
        return qn - 2.0 * q @ a.T + an[None]

    # device-select variants (``select="device"``): same scorer expression,
    # but the per-candidate distance vector ``dmat[step_row, arange]`` is
    # gathered ON DEVICE — only a (n_pad,) f32 vector crosses to the host,
    # never the (qb_pad, n_pad) step block.  Same floats as the host
    # gather, so the prune band (and hence the trajectory) is unchanged.
    @functools.partial(jax.jit, static_argnames=("interpret",))
    def pallas_vec(q, xdev, idx, step_row, interpret=True):
        from ..kernels.l2_topk import l2_dist

        dmat = l2_dist(q, xdev[idx], block_q=GRAPH_BLOCK_Q,
                       block_n=GRAPH_BLOCK_N, interpret=interpret)
        return dmat[step_row, jnp.arange(idx.shape[0])]

    @jax.jit
    def xla_vec(q, xdev, idx, step_row):
        a = xdev[idx]
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        an = jnp.sum(a * a, axis=1)
        dmat = qn - 2.0 * q @ a.T + an[None]
        return dmat[step_row, jnp.arange(idx.shape[0])]

    return {"pallas": pallas, "xla": xla,
            "pallas_vec": pallas_vec, "xla_vec": xla_vec}


def _device_base(index):
    """Device copy of ``index.x``, uploaded once and cached on the index
    (invalidated when ``add()`` swaps the base matrix)."""
    cached = getattr(index, "_graph_scan_xdev", None)
    if cached is None or cached[0] is not index.x:
        cached = (index.x, _jax().numpy.asarray(
            np.ascontiguousarray(index.x, np.float32)))
        index._graph_scan_xdev = cached
    return cached[1]


class _BeamState:
    """Masked-array bookkeeping for one block of beams (no Python heaps).

    Per query row: a frontier (unordered array + vectorized argmin pops;
    slots past ``f_len`` hold +inf), a beam of at most ``ef`` results
    with a cached row maximum (worst entry evicted on overflow, oracle
    tie order), and a visited bitmap.  Floats are stored at full width so
    comparisons reproduce the oracle's Python-float semantics exactly.
    """

    def __init__(self, qb: int, n: int, ef: int):
        self.qb, self.n, self.ef = qb, n, ef
        cap = 64
        self.f_d = np.full((qb, cap), np.inf, np.float64)
        self.f_v = np.zeros((qb, cap), np.int64)
        self.f_len = np.zeros(qb, np.int64)
        bcap = max(ef, 1) + 1           # one overflow slot for evict-on-push
        self.b_d = np.zeros((qb, bcap), np.float64)
        self.b_v = np.zeros((qb, bcap), np.int64)
        self.b_len = np.zeros(qb, np.int64)
        self.b_max = np.zeros(qb, np.float64)
        self.visited = np.zeros((qb, n), bool)
        self.active = np.ones(qb, bool)

    def seed(self, entry: int, d0: np.ndarray) -> None:
        """Every beam starts at the entry point (oracle init)."""
        self.f_d[:, 0] = d0
        self.f_v[:, 0] = entry
        self.f_len[:] = 1
        self.b_d[:, 0] = d0
        self.b_v[:, 0] = entry
        self.b_len[:] = 1
        self.b_max[:] = d0
        self.visited[:, entry] = True

    def pop_all(self):
        """One lockstep pop: every active beam removes its frontier minimum
        (ties: lower id); beams whose minimum can no longer improve a full
        beam — or whose frontier is empty — deactivate (oracle stop rule).
        Returns (rows, nodes) of the successful pops."""
        act = np.flatnonzero(self.active)
        alive = self.f_len[act] > 0
        self.active[act[~alive]] = False
        act = act[alive]
        if act.size == 0:
            return act, act
        # steady state has every beam live: skip the row-gather copy
        sub_d = self.f_d if act.size == self.qb else self.f_d[act]
        sub_v = self.f_v if act.size == self.qb else self.f_v[act]
        m = sub_d.min(axis=1)           # inf padding keeps slots inert
        # column of the lexicographic (d, v) minimum per row
        vm = np.where(sub_d == m[:, None], sub_v, _VMAX)
        j = np.argmin(vm, axis=1)
        stop = (self.b_len[act] >= self.ef) & (m > self.b_max[act])
        self.active[act[stop]] = False
        act, m, j = act[~stop], m[~stop], j[~stop]
        if act.size == 0:
            return act, act
        u = self.f_v[act, j]
        last = self.f_len[act] - 1      # swap-with-last removal
        self.f_d[act, j] = self.f_d[act, last]
        self.f_v[act, j] = self.f_v[act, last]
        self.f_d[act, last] = np.inf
        self.f_len[act] = last
        return act, u

    def admit_all(self, rows: np.ndarray, vs: np.ndarray, ds: np.ndarray,
                  rank: np.ndarray, starts: np.ndarray, counts: np.ndarray,
                  beams: np.ndarray, erow: np.ndarray) -> None:
        """Exact sequential admission for a whole step, in closed form.

        The oracle processes each beam's survivors in friend-list order:
        accept when the beam is short or the distance beats the beam
        maximum, then evict the worst entry (ties: lower id).  Two facts
        replace that loop with vectorized counting + one row sort:

        * A rejected survivor is, when processed, >= the beam's ef-th
          smallest distance, and that threshold only tightens afterwards
          — so pooling rejected survivors with the accepted ones never
          changes the ef-th smallest VALUE.  Hence survivor j is accepted
          iff fewer than ef elements of (live beam entries ∪ ALL earlier
          survivors of its beam this step) are <= it: a pure counting
          test with no dependence on the acceptance sequence.
        * Every evicted entry is, at eviction time, the (d asc, id desc)
          maximum of its beam, and later arrivals are strictly better —
          so the final beam is exactly the ef smallest elements of
          (old beam ∪ accepted) under (d asc, id desc).

        ``rank``/``starts``/``counts``/``beams``/``erow`` describe the
        per-beam contiguous runs of (rows, vs, ds).
        """
        ef = self.ef
        B = beams.shape[0]
        live = np.arange(ef)[None, :] < self.b_len[beams][:, None]
        oldm = np.where(live, self.b_d[beams, :ef], np.inf)
        cnt_old = (oldm[erow] <= ds[:, None]).sum(axis=1)
        mm = int(counts.max())
        dvp = np.full((B, mm), np.inf)
        dvp[erow, rank] = ds
        tri = np.arange(mm)[:, None] > np.arange(mm)[None, :]
        pc = ((dvp[:, None, :] <= dvp[:, :, None]) & tri[None]).sum(axis=-1)
        acc = cnt_old + pc[erow, rank] < ef
        # frontier pushes: accepted survivors, within-beam order preserved
        csum = np.cumsum(acc)
        acnt = csum[starts + counts - 1] - csum[starts] + acc[starts]
        aoff = csum - 1 - (csum[starts] - acc[starts])[erow]
        rows_a = rows[acc]
        fpos = self.f_len[rows_a] + aoff[acc]
        self.f_d[rows_a, fpos] = ds[acc]
        self.f_v[rows_a, fpos] = vs[acc]
        self.f_len[beams] += acnt
        # beams: one (d asc, id desc) row sort of old ∪ accepted; slots
        # past the new length come out as +inf and are never read
        d_mrg = np.full((B, ef + mm), np.inf)
        d_mrg[:, :ef] = oldm
        v_mrg = np.full((B, ef + mm), -1, np.int64)
        v_mrg[:, :ef] = self.b_v[beams, :ef]
        d_mrg[erow, ef + rank] = np.where(acc, ds, np.inf)
        v_mrg[erow, ef + rank] = vs
        order = np.lexsort((-v_mrg, d_mrg), axis=-1)[:, :ef]
        brow = np.arange(B)[:, None]
        d_keep = d_mrg[brow, order]
        self.b_d[beams, :ef] = d_keep
        self.b_v[beams, :ef] = v_mrg[brow, order]
        newlen = np.minimum(self.b_len[beams] + acnt, ef)
        self.b_len[beams] = newlen
        self.b_max[beams] = d_keep[np.arange(B), newlen - 1]

    def reserve(self, beams: np.ndarray, counts: np.ndarray) -> None:
        """One capacity check per step: after this, every insert path may
        push up to ``counts`` entries per beam without further checks.
        Compaction is tried before growing — it usually wins, keeping the
        frontier arrays (and every pop's scan width) small."""
        need = int((self.f_len[beams] + counts).max())
        if need <= self.f_d.shape[1]:
            return
        self.compact()
        need = int((self.f_len[beams] + counts).max())
        while need > self.f_d.shape[1]:
            self.f_d = np.concatenate(
                [self.f_d, np.full_like(self.f_d, np.inf)], axis=1)
            self.f_v = np.concatenate(
                [self.f_v, np.zeros_like(self.f_v)], axis=1)

    def compact(self) -> None:
        """Drop frontier entries that can never be popped.  Once a beam is
        full its stop/admission threshold (the beam maximum) only
        tightens, so entries strictly worse than it are dead weight: a pop
        that would select one deactivates the beam first — and an emptied
        frontier deactivates it the same way."""
        thr = np.where(self.b_len >= self.ef, self.b_max, np.inf)
        keep = self.f_d <= thr[:, None]
        cols = np.arange(self.f_d.shape[1])[None, :]
        keep &= cols < self.f_len[:, None]   # padding is not a real entry
        order = np.argsort(~keep, axis=1, kind="stable")
        self.f_d = np.take_along_axis(self.f_d, order, axis=1)
        self.f_v = np.take_along_axis(self.f_v, order, axis=1)
        self.f_len = keep.sum(axis=1)
        self.f_d[cols >= self.f_len[:, None]] = np.inf

    def insert_bulk(self, rows: np.ndarray, vs: np.ndarray, ds: np.ndarray,
                    off: np.ndarray, beams: np.ndarray,
                    counts: np.ndarray) -> None:
        """All survivors of beams that cannot overflow this step
        (``b_len + count <= ef``): every insert runs with a short beam, so
        the oracle accepts unconditionally and never evicts — one
        vectorized append replaces the whole sequential loop.  ``off`` is
        each element's position within its beam's group."""
        fl = self.f_len[rows] + off
        self.f_d[rows, fl] = ds
        self.f_v[rows, fl] = vs
        bl = self.b_len[rows] + off
        self.b_d[rows, bl] = ds
        self.b_v[rows, bl] = vs
        self.f_len[beams] += counts
        self.b_len[beams] += counts
        gmax = np.maximum.reduceat(ds, np.cumsum(counts) - counts)
        self.b_max[beams] = np.maximum(self.b_max[beams], gmax)

    def results(self, i: int, topk: int):
        """(ids, dists) sorted by (distance, id) — the oracle's final sort."""
        bl = int(self.b_len[i])
        order = np.lexsort((self.b_v[i, :bl], self.b_d[i, :bl]))[:topk]
        return self.b_v[i, order], self.b_d[i, order]


def batched_graph_search(index, queries: np.ndarray, ef: int = 16,
                         topk: int = 10, engine: str = "auto",
                         query_block: int = DEFAULT_QUERY_BLOCK,
                         kernel_min: int | None = None,
                         select: str = "auto"):
    """Beam-batched search; bit-identical to ``index.search_ref``.

    ``kernel_min`` is the smallest candidate tile that takes the device
    scorer (kernel distances only prune, so the gate never changes
    results).  Default: one kernel block on accelerators; a much fuller
    tile on CPU, where the scorer competes with the host re-score it
    cannot replace and dispatch only amortizes across a wide tile.

    ``select`` places the per-step distance gather: ``"host"`` pulls the
    whole scored ``(qb_pad, n_pad)`` step block and gathers
    ``dmat[step_row, arange]`` in numpy; ``"device"`` gathers on device
    so only the ``(n_pad,)`` candidate-distance vector crosses to the
    host (``stats.host_block_bytes`` / ``stats.device_select`` are the
    ledger); ``"auto"`` selects on device off-CPU.  Either way the same
    floats feed the same prune, and the exact numpy re-score decides
    admission — results are bit-identical across ``select`` × ``engine``.

    Returns ``(ids (nq, topk) int64, dists (nq, topk) f32, SearchStats)``.
    """
    engine = _resolve_engine(engine)
    if select not in ("auto", "host", "device"):
        raise ValueError(f"unknown select mode {select!r} "
                         "(options: auto, host, device)")
    interpret = _jax().default_backend() == "cpu"
    if kernel_min is None:
        kernel_min = GRAPH_BLOCK_N * (8 if interpret else 1)
    dev_sel = select == "device" or (select == "auto" and not interpret)
    scorer = _graph_scorers()[engine + "_vec" if dev_sel else engine]
    xdev = _device_base(index)
    t0 = time.perf_counter()
    queries = np.asarray(queries)
    nq, n, d = queries.shape[0], index.n, index.x.shape[1]
    ids = np.zeros((nq, topk), np.int64)
    dists = np.full((nq, topk), np.inf, np.float32)
    q32 = queries.astype(np.float32, copy=False)
    qn_host = np.einsum("qd,qd->q", q32, q32)
    cache = index.decoded_cache
    decodes0 = cache.decodes
    ndis = hops = steps = frontier_size = dedup_hits = 0
    host_block_bytes = 0
    n_dev_select = 0
    # base term of scan.rescore_eps; vectorized below as
    # f32eps * (1 + |bound| + qn) == rescore_eps(d, bound, qn, factor)
    f32eps = rescore_eps(d, 0.0, 0.0, PRUNE_EPS_FACTOR)

    for q0 in range(0, nq, query_block):
        q1 = min(nq, q0 + query_block)
        qb = q1 - q0
        qblk_src = queries[q0:q1]
        state = _BeamState(qb, n, ef)
        # oracle init: per-query scalar entry distance (same numpy expression)
        d0 = np.empty(qb, np.float64)
        for i in range(qb):
            d0[i] = float(np.sum((index.x[index.entry] - qblk_src[i]) ** 2))
        ndis += qb
        state.seed(index.entry, d0)
        # per-block memo over the shared cache: a node expanded by ANY beam
        # at ANY step of this block is decoded at most once
        friends: Dict[int, np.ndarray] = {}

        while state.active.any():
            steps += 1
            frontier_size += int(state.active.sum())
            rows, nodes = state.pop_all()
            if rows.size == 0:
                continue
            hops += rows.size
            # -- shared frontier gather: decode each distinct node once -----
            fr_lists: List[np.ndarray] = []
            step_seen = set()
            for u in nodes:
                u = int(u)
                if u in step_seen:
                    dedup_hits += 1
                else:
                    step_seen.add(u)
                fl_ = friends.get(u)
                if fl_ is None:
                    fl_ = friends[u] = index._friends(u)
                fr_lists.append(fl_)
            # -- unvisited filter, all beams at once ------------------------
            # each beam pops exactly one node per step and friend lists hold
            # no repeats, so the (row, friend) pairs are unique and one
            # fancy-index pass filters + marks every beam (friend-list
            # order within each beam is preserved by the grouped concat)
            lens = np.fromiter((f.shape[0] for f in fr_lists), np.int64,
                               len(fr_lists))
            if not int(lens.sum()):
                continue
            all_v = np.concatenate(fr_lists)
            all_row = np.repeat(rows, lens)
            fresh = ~state.visited[all_row, all_v]
            cand_v, cand_row = all_v[fresh], all_row[fresh]
            if cand_v.size == 0:
                continue
            state.visited[cand_row, cand_v] = True
            ndis += cand_v.size
            # -- one blocked distance computation for the whole step --------
            # (only when the tile clears the kernel_min gate: the kernel
            # distances are a prune, never a decision, so narrow steps
            # skip the device round trip and go straight to the exact
            # host re-score)
            if cand_v.size >= kernel_min:
                # beams appear as ascending contiguous runs: run boundaries
                # give the query-tile row per candidate without a sort
                mark = np.empty(cand_row.shape[0], bool)
                mark[0] = True
                np.not_equal(cand_row[1:], cand_row[:-1], out=mark[1:])
                step_row = np.cumsum(mark) - 1
                beam_rows = cand_row[mark]
                # candidates go in as-is (a cross-beam repeat is scored
                # twice — cheaper than a sort-based dedup of the tile)
                idx_pad = np.zeros(
                    _bucket(cand_v.shape[0], floor=GRAPH_BLOCK_N), np.int32)
                idx_pad[:cand_v.shape[0]] = cand_v
                qblk = np.zeros((_bucket(beam_rows.shape[0], floor=8), d),
                                np.float32)
                qblk[:beam_rows.shape[0]] = q32[q0 + beam_rows]
                # -- exact admission: kernel prunes, numpy decides ----------
                # the admission bound only tightens as a step's survivors
                # are inserted, so the step-entry bound plus the kernel
                # error band is a sound prune for full beams; short beams
                # keep everything
                if dev_sel:
                    n_dev_select += 1
                    srow = np.zeros(idx_pad.shape[0], np.int32)
                    srow[:cand_v.shape[0]] = step_row
                    if engine == "pallas":
                        kd = scorer(qblk, xdev, idx_pad, srow,
                                    interpret=interpret)
                    else:
                        kd = scorer(qblk, xdev, idx_pad, srow)
                    kd = np.asarray(kd)
                    host_block_bytes += kd.nbytes
                    kd = kd[:cand_v.shape[0]]
                else:
                    if engine == "pallas":
                        dmat = scorer(qblk, xdev, idx_pad,
                                      interpret=interpret)
                    else:
                        dmat = scorer(qblk, xdev, idx_pad)
                    dmat = np.asarray(dmat)
                    host_block_bytes += dmat.nbytes
                    kd = dmat[step_row, np.arange(cand_v.shape[0])]
                full = state.b_len[cand_row] >= ef
                tau = state.b_max[cand_row]
                eps = f32eps * (1.0 + np.abs(tau) + qn_host[q0 + cand_row])
                keep = ~full | (kd <= tau + eps)
                cand_v, cand_row = cand_v[keep], cand_row[keep]
                if cand_v.size == 0:
                    continue
            # oracle's scalar path on the survivors (per-row reduction is
            # independent of which other rows are stacked with it)
            dv = np.sum((index.x[cand_v] - qblk_src[cand_row]) ** 2, axis=1)
            # -- admission ---------------------------------------------------
            # beams are independent: only WITHIN-beam order is semantic, and
            # the grouped concat keeps friend-list order per beam.  Beams
            # that cannot overflow this step take the bulk append (the
            # sequential loop degenerates to accept-all); everything else
            # goes through the closed-form admission (see admit_all)
            T = cand_v.shape[0]
            mark = np.empty(T, bool)
            mark[0] = True
            np.not_equal(cand_row[1:], cand_row[:-1], out=mark[1:])
            starts = np.flatnonzero(mark)
            counts = np.empty(starts.shape[0], np.int64)
            counts[:-1] = starts[1:] - starts[:-1]
            counts[-1] = T - starts[-1]
            beams = cand_row[starts]
            state.reserve(beams, counts)
            rank = np.arange(T) - np.repeat(starts, counts)
            no_ov = state.b_len[beams] + counts <= ef
            if no_ov.all():
                state.insert_bulk(cand_row, cand_v, dv, rank, beams, counts)
                continue
            erow = np.repeat(np.arange(beams.shape[0]), counts)
            state.admit_all(cand_row, cand_v, dv, rank, starts, counts,
                            beams, erow)

        for i in range(qb):
            rv, rd = state.results(i, topk)
            ids[q0 + i, :rv.shape[0]] = rv
            dists[q0 + i, :rd.shape[0]] = rd

    stats = SearchStats(
        wall_s=time.perf_counter() - t0,
        ndis=ndis,
        id_resolve_s=0.0,
        decodes=cache.decodes - decodes0,
        engine=f"graph-{engine}",
        visited=hops,
        steps=steps,
        frontier_size=frontier_size,
        dedup_hits=dedup_hits,
        host_block_bytes=host_block_bytes,
        device_select=n_dev_select,
    )
    return ids, dists, stats
