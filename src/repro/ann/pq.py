"""Product Quantizer (Jegou et al. [30]) — train / encode / ADC tables.

``PQmxb``: m subquantizers of b bits (default 8 -> 256 centroids each).
ADC (asymmetric distance computation): per query, a (m, 2^b) table of
squared distances from the query sub-vector to each centroid; a database
code's distance is the sum of m table lookups — the scan the paper's
Table 2 times, and the compute pattern of ``repro.kernels.pq_adc``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .kmeans import assign, kmeans

__all__ = ["ProductQuantizer"]


@dataclasses.dataclass
class ProductQuantizer:
    m: int
    bits: int
    codebooks: np.ndarray | None = None  # (m, 2^bits, d_sub)

    @property
    def ksub(self) -> int:
        return 1 << self.bits

    def train(self, x: np.ndarray, iters: int = 8, seed: int = 0) -> "ProductQuantizer":
        n, d = x.shape
        assert d % self.m == 0, "dim must divide m"
        dsub = d // self.m
        cb = np.zeros((self.m, self.ksub, dsub), np.float32)
        for j in range(self.m):
            sub = x[:, j * dsub : (j + 1) * dsub].astype(np.float32)
            cb[j] = kmeans(sub, self.ksub, iters=iters, seed=seed + j)
        self.codebooks = cb
        return self

    def encode(self, x: np.ndarray) -> np.ndarray:
        n, d = x.shape
        dsub = d // self.m
        codes = np.zeros((n, self.m), np.uint8 if self.bits <= 8 else np.uint16)
        for j in range(self.m):
            sub = x[:, j * dsub : (j + 1) * dsub].astype(np.float32)
            codes[:, j] = assign(sub, self.codebooks[j])
        return codes

    def decode(self, codes: np.ndarray) -> np.ndarray:
        n = codes.shape[0]
        return np.concatenate(
            [self.codebooks[j][codes[:, j]] for j in range(self.m)], axis=1
        )

    def adc_tables(self, queries: np.ndarray) -> np.ndarray:
        """(nq, m, 2^bits) squared-distance lookup tables."""
        nq, d = queries.shape
        dsub = d // self.m
        tabs = np.zeros((nq, self.m, self.ksub), np.float32)
        for j in range(self.m):
            qs = queries[:, j * dsub : (j + 1) * dsub]
            diff = qs[:, None, :] - self.codebooks[j][None]
            tabs[:, j] = np.einsum("qkd,qkd->qk", diff, diff)
        return tabs

    @staticmethod
    def adc_score(codes: np.ndarray, table: np.ndarray) -> np.ndarray:
        """codes (n, m) + one query's table (m, 2^bits) -> (n,) distances."""
        m = codes.shape[1]
        return table[np.arange(m)[None, :], codes.astype(np.int64)].sum(axis=1)
