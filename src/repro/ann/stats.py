"""Per-call search statistics, shared by every index type.

One stats shape for the whole index layer (IVF scan, graph best-first,
flat brute force) so ``repro.serve.AnnService`` and the benchmarks can
aggregate decode/latency counters without caring which structure served
the batch.  Fields that do not apply to a given index type stay at their
zero default (e.g. ``visited`` for IVF, ``batches`` for graphs).
"""

from __future__ import annotations

import dataclasses

__all__ = ["SearchStats"]


@dataclasses.dataclass
class SearchStats:
    wall_s: float
    ndis: int                  # distance evaluations this call
    id_resolve_s: float        # late id-resolution time (IVF §4.1; 0 for graphs)
    decodes: int = 0           # id-list decode events this call (LRU misses)
    distinct_probed: int = 0   # distinct clusters probed across the batch (IVF)
    batches: int = 0           # query blocks scanned (0 for search_ref/graphs)
    engine: str = "ref"        # "pallas" | "xla" | "ref" | "graph*" | "flat"
    visited: int = 0           # graph nodes expanded (0 for IVF/flat)
    steps: int = 0             # lockstep beam iterations (batched graph only)
    frontier_size: int = 0     # sum of active beams over steps (graph batched)
    dedup_hits: int = 0        # same-step friend-list fetches shared across beams
