"""Per-call search statistics, shared by every index type.

One stats shape for the whole index layer (IVF scan, graph best-first,
flat brute force) so ``repro.serve.AnnService`` and the benchmarks can
aggregate decode/latency counters without caring which structure served
the batch.  Fields that do not apply to a given index type stay at their
zero default (e.g. ``visited`` for IVF, ``batches`` for graphs).

The sharded router (``repro.shard.ShardedAnnService``) reports through
the same shape: :func:`combine_stats` sums the per-shard counters of one
scattered batch (wall time is the *max* across shards — they run in
parallel) and the fault layer fills ``shards`` / ``shards_failed`` /
``partial`` / ``retries`` so a degraded answer is visible in-band
instead of as an exception.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

__all__ = ["SearchStats", "combine_stats"]


@dataclasses.dataclass
class SearchStats:
    wall_s: float
    ndis: int                  # distance evaluations this call
    id_resolve_s: float        # late id-resolution time (IVF §4.1; 0 for graphs)
    decodes: int = 0           # id-list decode events this call (LRU misses)
    distinct_probed: int = 0   # distinct clusters probed across the batch (IVF)
    batches: int = 0           # query blocks scanned (0 for search_ref/graphs)
    engine: str = "ref"        # "pallas" | "xla" | "ref" | "graph*" | "flat"
    visited: int = 0           # graph nodes expanded (0 for IVF/flat)
    steps: int = 0             # lockstep beam iterations (batched graph only)
    frontier_size: int = 0     # sum of active beams over steps (graph batched)
    dedup_hits: int = 0        # same-step friend-list fetches shared across beams
    # -- device-side top-k select ledger (repro.kernels.seg_topk) ------------
    # bytes of device-computed distance data copied to the host this call:
    # the full (qb, C_pad) block on the host-select path, only the (qb, K)
    # shortlists on the device-select path — the proof the block never
    # materialized host-side when device_select covers every block/step
    host_block_bytes: int = 0
    device_select: int = 0     # query blocks / graph steps selected on device
    # -- sharded-serving aggregation (repro.shard) ---------------------------
    shards: int = 0            # shards scattered to (0 = unsharded call)
    shards_failed: int = 0     # shards that missed the deadline / died
    partial: bool = False      # True when results merged from < all shards
    retries: int = 0           # per-shard attempts beyond the first
    # (nq, topk) uint64 stable-merge keys, only filled when the caller asked
    # for them (``with_keys=True``): the monolithic tie order of each result,
    # so a sharded merge can reproduce the unsharded output bit-for-bit.
    merge_keys: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)


def combine_stats(parts: Sequence[SearchStats], *, wall_s: float,
                  merge_s: float = 0.0) -> SearchStats:
    """Sum per-shard stats of one scattered batch into one report.

    Counters add; ``wall_s`` is supplied by the caller (shards run
    concurrently, so per-shard walls overlap — pass the scatter+merge
    wall clock); ``merge_s`` is folded into ``id_resolve_s`` as the
    router's post-search bookkeeping cost.  ``engine`` is taken from the
    first part (shards of one plan share an engine).
    """
    out = SearchStats(wall_s=wall_s, ndis=0, id_resolve_s=merge_s,
                      engine=parts[0].engine if parts else "ref")
    for s in parts:
        out.ndis += s.ndis
        out.id_resolve_s += s.id_resolve_s
        out.decodes += s.decodes
        out.distinct_probed += s.distinct_probed
        out.batches += s.batches
        out.visited += s.visited
        out.steps += s.steps
        out.frontier_size += s.frontier_size
        out.dedup_hits += s.dedup_hits
        out.host_block_bytes += s.host_block_bytes
        out.device_select += s.device_select
        out.retries += s.retries
    return out
