"""RetrievalIndex — embedder + factory spec, the paper's technique as a
first-class framework feature.

Ties the LM side to the ANN side: embeddings from any supported arch
(mean-pooled hidden states) are indexed by **any** ``repro.api`` factory
spec — IVF with compressed ids (and optionally PQ codes), NSG/HNSW with
compressed friend lists, or a flat oracle.  Serving uses the §4.1
late-resolution trick, so the compressed ids cost O(topk) decode work
per query.  This is the component a kNN-LM / RAG deployment would mount
next to the model server; ``save``/``load`` persist it as one RIDX v2
artifact (the index-as-first-class-unit storage model).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..api import index_factory, load_index, save_index
from ..api.spec import IndexSpec
from ..configs.base import ModelConfig
from ..models import build

__all__ = ["RetrievalIndex", "embed_corpus"]


def embed_corpus(cfg: ModelConfig, params, token_batches) -> np.ndarray:
    """Mean-pooled final hidden states as document embeddings."""
    model = build(cfg)

    @jax.jit
    def embed_fn(p, tokens):
        logits, _ = model.apply(p, tokens=tokens, remat=False)
        # use pre-logits pooled representation: logits @ pinv is overkill;
        # mean over sequence of the final logits' top-vocab slice is a cheap
        # stand-in; real deployments hook the final_norm output instead.
        return logits.mean(axis=1)

    outs = [np.asarray(embed_fn(params, jnp.asarray(t))) for t in token_batches]
    x = np.concatenate(outs, axis=0).astype(np.float32)
    # project to a manageable dim for indexing
    rng = np.random.default_rng(0)
    proj = rng.standard_normal((x.shape[1], 64)).astype(np.float32) / 8.0
    return x @ proj


@dataclasses.dataclass
class RetrievalIndex:
    """Thin composition: a factory ``spec`` string over corpus embeddings.

    The legacy constructor knobs (``nlist``/``id_codec``/``pq_m``/
    ``code_codec``) are kept and synthesize a spec when ``spec`` is not
    given explicitly.
    """

    nlist: int = 64
    id_codec: str = "roc"
    pq_m: int = 0
    code_codec: Optional[str] = None
    spec: Optional[str] = None

    def __post_init__(self) -> None:
        if self.spec is None:
            self.spec = str(IndexSpec(
                kind="ivf", nlist=self.nlist, ids=self.id_codec,
                pq_m=self.pq_m, codes=self.code_codec))

    def build(self, embeddings: np.ndarray) -> "RetrievalIndex":
        self.index = index_factory(self.spec).build(embeddings)
        return self

    @property
    def ivf(self):
        """The underlying IVFIndex (legacy accessor; IVF specs only)."""
        return self.index.ivf

    def search(self, queries: np.ndarray, topk: int = 10, **opts):
        """Returns ``(ids, dists, stats)`` (legacy I/D order kept)."""
        dists, ids, stats = self.index.search(queries, k=topk, **opts)
        return ids, dists, stats

    def search_ref(self, queries: np.ndarray, nprobe: int = 8,
                   topk: int = 10):
        """Per-query oracle scan (see IVFIndex.search_ref; IVF specs only)."""
        return self.index.ivf.search_ref(queries, nprobe=nprobe, topk=topk)

    def stats(self) -> dict:
        led = self.index.memory_ledger()
        n = led["n"]
        out = {
            "n": n,
            "spec": self.index.spec,
            "compact_bits": float(np.ceil(np.log2(max(2, n)))),
            "memory_ledger": led,
        }
        inner = getattr(self.index, "ivf", None)
        if inner is not None:
            out["bits_per_id"] = inner.bits_per_id()
            out["code_bits_per_element"] = inner.code_bits_per_element()
            out["decoded_cache"] = inner.decoded_cache.stats()
        graph = getattr(self.index, "graph", None)
        if graph is not None:
            out["bits_per_edge"] = graph.bits_per_edge()
            out["decoded_cache"] = graph.decoded_cache.stats()
        return out

    # -- persistence (RIDX v2) ------------------------------------------------
    def save(self, path=None) -> bytes:
        return save_index(self.index, path)

    @classmethod
    def load(cls, src) -> "RetrievalIndex":
        index = load_index(src)
        ri = cls(spec=index.spec)
        ri.index = index
        return ri
