"""RetrievalIndex — the paper's technique as a first-class framework feature.

Ties the LM side to the ANN side: embeddings from any supported arch (mean-
pooled hidden states) are indexed in an IVF structure whose inverted-list
ids (and optionally PQ codes) are stored losslessly compressed.  Serving
uses the §4.1 late-resolution trick, so the compressed ids cost O(topk)
decode work per query.  This is the component a kNN-LM / RAG deployment
would mount next to the model server.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ann.ivf import IVFIndex
from ..ann.pq import ProductQuantizer
from ..configs.base import ModelConfig
from ..models import build

__all__ = ["RetrievalIndex", "embed_corpus"]


def embed_corpus(cfg: ModelConfig, params, token_batches) -> np.ndarray:
    """Mean-pooled final hidden states as document embeddings."""
    model = build(cfg)

    @jax.jit
    def embed_fn(p, tokens):
        logits, _ = model.apply(p, tokens=tokens, remat=False)
        # use pre-logits pooled representation: logits @ pinv is overkill;
        # mean over sequence of the final logits' top-vocab slice is a cheap
        # stand-in; real deployments hook the final_norm output instead.
        return logits.mean(axis=1)

    outs = [np.asarray(embed_fn(params, jnp.asarray(t))) for t in token_batches]
    x = np.concatenate(outs, axis=0).astype(np.float32)
    # project to a manageable dim for indexing
    rng = np.random.default_rng(0)
    proj = rng.standard_normal((x.shape[1], 64)).astype(np.float32) / 8.0
    return x @ proj


@dataclasses.dataclass
class RetrievalIndex:
    nlist: int = 64
    id_codec: str = "roc"
    pq_m: int = 0
    code_codec: Optional[str] = None

    def build(self, embeddings: np.ndarray) -> "RetrievalIndex":
        pq = ProductQuantizer(m=self.pq_m, bits=8) if self.pq_m else None
        self.ivf = IVFIndex(nlist=self.nlist, id_codec=self.id_codec,
                            pq=pq, code_codec=self.code_codec).build(embeddings)
        return self

    def search(self, queries: np.ndarray, nprobe: int = 8, topk: int = 10,
               engine: str = "auto"):
        return self.ivf.search(queries, nprobe=nprobe, topk=topk,
                               engine=engine)

    def search_ref(self, queries: np.ndarray, nprobe: int = 8,
                   topk: int = 10):
        """Per-query oracle scan (see IVFIndex.search_ref)."""
        return self.ivf.search_ref(queries, nprobe=nprobe, topk=topk)

    def stats(self) -> dict:
        return {
            "n": self.ivf.n,
            "bits_per_id": self.ivf.bits_per_id(),
            "compact_bits": float(np.ceil(np.log2(self.ivf.n))),
            "code_bits_per_element": self.ivf.code_bits_per_element(),
            "decoded_cache": self.ivf.decoded_cache.stats(),
        }
