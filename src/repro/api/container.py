"""RIDX v3 — one versioned container for *any* factory-built index.

Generalizes the v1 ``RIVF`` IVF-only blob (``repro.core.container``) to a
manifest-of-sections format whose manifest records the index's canonical
factory spec.  ``load_index(save_index(idx))`` returns an index whose
search results are **bit-identical** to the original:

* centroids / vectors / PQ codebooks are stored as exact f32 (the v1
  container's f16 centroids would perturb coarse probes);
* IVF id lists ride in joint exact-ANS ROC streams (§4.3 offline
  setting, ``log n_k!`` collected per cluster) — **one per epoch** since
  v3: the manifest carries the epoch table (``[base, count]`` rows) and
  per-epoch ``ids{e}`` / ``esizes`` sections, so an index mid-ingest
  round-trips losslessly *including* its epoch structure and therefore
  its exact ``id_bits()`` accounting;
* PQ codes go through the Pólya coder when the index carries one — also
  one blob per epoch (``code{e}_*`` sections);
* graph edge lists go through the offline path — webgraph-lite by
  default, Random Edge Coding (``graph_codec="rec"``, static degree
  model + shipped degree table) on request; per-node encoding universes
  (the graph ingest analogue of epochs) ride as an RLE section;
* per-list online blobs (ROC/EF/...) and the wavelet tree are *not*
  stored: they are deterministic functions of (lists, universe) and are
  re-encoded per epoch on load, so ``id_bits()`` bookkeeping round-trips.

v2 containers (single implicit epoch, all graph universes = n) still
load; new blobs are always written as v3.
"""

from __future__ import annotations

import os
from typing import List, Optional, Union

import numpy as np

from ..ann.graph import GraphIndex
from ..ann.ivf import IVFIndex
from ..ann.pq import ProductQuantizer
from ..core.ans import StreamANS
from ..core.container import (SectionReader, SectionWriter, pack_joint_ids,
                              pack_polya_sections, unpack_joint_ids,
                              unpack_polya_sections)
from ..core.epoch import EpochStore, wt_sequence
from ..core.polya import PolyaCodec
from ..core.rec import RECResult, _degree_table, rec_decode, rec_encode
from ..core.webgraph_lite import webgraph_decode, webgraph_encode
from .indexes import FlatIndex, GraphApiIndex, IVFApiIndex, as_api_index
from .spec import IndexSpec, parse_spec

__all__ = ["pack_index", "unpack_index", "save_index", "load_index",
           "wt_sequence", "RIDX_MAGIC", "RIDX_VERSION"]

RIDX_MAGIC = b"RIDX"
RIDX_VERSION = 3


# ---------------------------------------------------------------------------
# pack
# ---------------------------------------------------------------------------

def pack_index(index, graph_codec: str = "webgraph") -> bytes:
    """Serialize any factory-built (or raw IVF/Graph) index to one blob."""
    index = as_api_index(index)
    spec = parse_spec(index.spec)
    meta = {"spec": str(spec), "kind": spec.kind}
    w = SectionWriter()
    if isinstance(index, FlatIndex):
        meta.update(n=int(index.n), d=int(index.d))
        w.add("vecs", index.vecs.astype(np.float32).tobytes())
        id_map = getattr(index, "id_map", None)
        if id_map is not None:
            meta["id_map"] = True
            w.add("id_map", np.asarray(id_map, np.int64).tobytes())
    elif isinstance(index, IVFApiIndex):
        _pack_ivf_sections(w, meta, index.ivf)
    elif isinstance(index, GraphApiIndex):
        _pack_graph_sections(w, meta, index.graph, graph_codec)
    else:  # pragma: no cover - as_api_index guarantees one of the above
        raise TypeError(f"cannot pack {type(index).__name__}")
    return w.finish(RIDX_MAGIC, RIDX_VERSION, meta)


def _pack_ivf_sections(w: SectionWriter, meta: dict, ivf: IVFIndex) -> None:
    meta.update(n=int(ivf.n), d=int(ivf.d), nlist=int(ivf.nlist))
    w.add("sizes", ivf.sizes.astype(np.int64).tobytes())
    w.add("centroids", ivf.centroids.astype(np.float32).tobytes())
    # epoch table + one joint ROC stream per epoch (relative ids, epoch
    # universe) — lossless for an index mid-ingest
    store: EpochStore = ivf._ids
    meta["epochs"] = [[int(ep.base), int(ep.count)] for ep in store.epochs]
    w.add("esizes", np.stack(
        [ep.sizes for ep in store.epochs]).astype(np.int64).tobytes())
    for e, ep in enumerate(store.epochs):
        rel = store.rel_lists(e, ivf._lists)
        w.add(f"ids{e}", pack_joint_ids(rel, ep.count))
    meta["pq"] = ({"m": int(ivf.pq.m), "bits": int(ivf.pq.bits)}
                  if ivf.pq is not None else None)
    if ivf.pq is not None:
        w.add("pq_codebooks", ivf.pq.codebooks.astype(np.float32).tobytes())
    if ivf._code_blobs is not None:
        meta["code"] = {
            "m": int(ivf._code_blobs[0]["m"]),
            "epochs": [pack_polya_sections(w, blob, prefix=f"code{e}")
                       for e, blob in enumerate(ivf._code_blobs)],
        }
    elif ivf.codes is not None:
        w.add("codes_raw", ivf.codes.tobytes())
        meta["code"] = {"m": int(ivf.codes.shape[1]), "raw": True}
    else:
        meta["code"] = None
        w.add("vecs", ivf.vecs.astype(np.float32).tobytes())


def _rle(a: np.ndarray):
    """(values, run_lengths) run-length encoding of a 1-d array."""
    a = np.asarray(a, np.int64)
    if a.size == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    starts = np.concatenate([[0], np.flatnonzero(np.diff(a)) + 1])
    lens = np.diff(np.concatenate([starts, [a.size]]))
    return a[starts], lens.astype(np.int64)


def _pack_graph_sections(w: SectionWriter, meta: dict, g: GraphIndex,
                         graph_codec: str) -> None:
    meta.update(n=int(g.n), d=int(g.x.shape[1]), entry=int(g.entry),
                graph_codec=graph_codec)
    w.add("vecs", g.x.astype(np.float32).tobytes())
    id_map = getattr(g, "id_map", None)
    if id_map is not None:
        meta["id_map"] = True
        w.add("id_map", np.asarray(id_map, np.int64).tobytes())
    # per-node encoding universes: appends leave old nodes' blobs sealed at
    # the universe they were built with — RLE is tiny (one run per ingest
    # generation), and shipping it lets the loader re-encode each blob at
    # its original universe so id_bits round-trips mid-ingest
    universes = getattr(g, "_universes", None)
    if universes is None:
        universes = np.full(g.n, g.n, np.int64)
    vals, lens = _rle(universes)
    meta["universe_runs"] = int(vals.size)
    w.add("universes", np.concatenate([vals, lens]).tobytes())
    if graph_codec == "webgraph":
        ans = webgraph_encode(g.adj_raw, g.n)
        head, tail = ans.tobytes()
        w.add("graph_head", head)
        w.add("graph_tail", tail)
    elif graph_codec == "rec":
        edges = _edge_list(g.adj_raw)
        meta["n_edges"] = int(edges.shape[0])
        res = rec_encode(edges, g.n, model="degree")
        head, tail = res.state.tobytes()
        w.add("graph_head", head)
        w.add("graph_tail", tail)
        degrees = np.bincount(edges.reshape(-1), minlength=g.n)
        w.add("degrees", degrees.astype(np.int64).tobytes())
    else:
        raise ValueError(f"unknown graph_codec {graph_codec!r} "
                         "(options: webgraph, rec)")


def _edge_list(adj: List[np.ndarray]) -> np.ndarray:
    src = np.concatenate([np.full(len(a), i, np.int64)
                          for i, a in enumerate(adj)] or
                         [np.zeros(0, np.int64)])
    dst = (np.concatenate(adj) if any(len(a) for a in adj)
           else np.zeros(0, np.int64))
    return np.stack([src.astype(np.int64), dst.astype(np.int64)], axis=1)


# ---------------------------------------------------------------------------
# unpack
# ---------------------------------------------------------------------------

def unpack_index(raw: bytes):
    """Inverse of :func:`pack_index`: a ready-to-search api index."""
    r = SectionReader(raw, RIDX_MAGIC)
    if r.version not in (2, RIDX_VERSION):
        raise ValueError(f"unsupported RIDX version {r.version}")
    m = r.manifest
    spec = parse_spec(m["spec"])
    if spec.kind == "flat":
        idx = FlatIndex(spec)
        idx.n, idx.d = m["n"], m["d"]
        idx.vecs = _f32(r.section("vecs"), (m["n"], m["d"]))
        if m.get("id_map"):
            idx.id_map = np.frombuffer(r.section("id_map"), np.int64).copy()
        return idx
    if spec.kind == "ivf":
        return IVFApiIndex.from_built(_unpack_ivf(r, spec), spec)
    return GraphApiIndex.from_built(_unpack_graph(r, spec), spec)


def _f32(raw: bytes, shape) -> np.ndarray:
    return np.frombuffer(raw, np.float32).reshape(shape).copy()


def _cache_fields(spec: IndexSpec) -> dict:
    return dict(
        cache_bytes=(int(spec.cache_mb * (1 << 20))
                     if spec.cache_mb is not None else None),
        cache_policy=spec.cache_policy or "lru",
        max_epochs=spec.max_epochs,
    )


def _unpack_ivf(r: SectionReader, spec: IndexSpec) -> IVFIndex:
    m = r.manifest
    n, d, nlist = m["n"], m["d"], m["nlist"]
    pq = None
    if m["pq"]:
        pq = ProductQuantizer(m=m["pq"]["m"], bits=m["pq"]["bits"])
        pq.codebooks = _f32(r.section("pq_codebooks"),
                            (pq.m, pq.ksub, d // pq.m))
    ivf = IVFIndex(nlist=nlist, id_codec=spec.ids, pq=pq,
                   code_codec=spec.codes, **_cache_fields(spec))
    ivf.n, ivf.d = n, d
    ivf.sizes = np.frombuffer(r.section("sizes"), np.int64).copy()
    ivf.offsets = np.concatenate([[0], np.cumsum(ivf.sizes)]).astype(np.int64)
    ivf.centroids = _f32(r.section("centroids"), (nlist, d))
    # id lists + epoch structure; online blobs / the wavelet tree are
    # deterministic re-encodes from the decoded lists (per epoch), so
    # size_bits bookkeeping matches the pre-save index exactly
    ivf._ids = EpochStore(nlist, spec.ids)
    if r.version == 2:                     # v2: one implicit epoch [0, n)
        epochs = [[0, n]]
        esizes = ivf.sizes[None, :]
        rel_of = {0: unpack_joint_ids(r.section("ids"), ivf.sizes, n)}
    else:
        epochs = m["epochs"]
        esizes = np.frombuffer(r.section("esizes"), np.int64).reshape(
            len(epochs), nlist)
        rel_of = {
            e: unpack_joint_ids(r.section(f"ids{e}"), esizes[e], int(count))
            for e, (_, count) in enumerate(epochs)
        }
    per_epoch_abs = []
    for e, (base, count) in enumerate(epochs):
        ivf._ids.append(rel_of[e], int(base), int(count))
        per_epoch_abs.append([lst + int(base) for lst in rel_of[e]])
    ivf._lists = [
        np.concatenate([per_epoch_abs[e][k] for e in range(len(epochs))])
        for k in range(nlist)
    ]
    # assignment string (id -> cluster); also the storage permutation source
    ivf.cluster_of = np.zeros(n, np.int64)
    if n and int(ivf.sizes.sum()):
        ivf.cluster_of[np.concatenate(ivf._lists)] = np.repeat(
            np.arange(nlist, dtype=np.int64), ivf.sizes)
    # payload (cluster-grouped storage order)
    cm = m["code"]
    if cm is None:
        ivf.codes = None
        # shards store fewer rows than the global universe n
        ivf.vecs = _f32(r.section("vecs"), (int(ivf.sizes.sum()), d))
        ivf._code_blobs = None
    elif cm.get("raw"):
        ivf.vecs = None
        ivf.codes = np.frombuffer(r.section("codes_raw"), np.uint8).reshape(
            -1, cm["m"]).copy()
        ivf._code_blobs = None
    else:
        ivf.vecs = None
        ivf._polya = PolyaCodec()
        if r.version == 2:
            blob = unpack_polya_sections(r, [int(s) for s in ivf.sizes], cm)
            ivf._code_blobs = [blob]
            per_epoch_codes = [PolyaCodec().decode(blob)]
        else:
            ivf._code_blobs = []
            per_epoch_codes = []
            for e in range(len(epochs)):
                blob = unpack_polya_sections(
                    r, [int(s) for s in esizes[e]], cm["epochs"][e],
                    prefix=f"code{e}")
                ivf._code_blobs.append(blob)
                per_epoch_codes.append(PolyaCodec().decode(blob))
        # epoch-major per-cluster chunks -> global cluster-grouped rows
        ivf.codes = np.concatenate(
            [chunk
             for k in range(nlist)
             for per in per_epoch_codes
             for chunk in [per[k]]], axis=0)
    ivf._decoded_cache = ivf._new_cache()
    return ivf


def _unpack_graph(r: SectionReader, spec: IndexSpec) -> GraphIndex:
    from ..core.codecs import get_codec

    m = r.manifest
    n, d = m["n"], m["d"]
    g = GraphIndex(id_codec=spec.ids, **_cache_fields(spec))
    g.n = n
    g.x = _f32(r.section("vecs"), (n, d))
    g.entry = int(m["entry"])
    if m.get("id_map"):
        g.id_map = np.frombuffer(r.section("id_map"), np.int64).copy()
    if m["graph_codec"] == "webgraph":
        ans = StreamANS.frombytes(r.section("graph_head"),
                                  r.section("graph_tail"))
        g.adj_raw = [a.astype(np.int64) for a in webgraph_decode(ans, n, n)]
    else:  # rec
        degrees = np.frombuffer(r.section("degrees"), np.int64)
        ans = StreamANS.frombytes(r.section("graph_head"),
                                  r.section("graph_tail"))
        res = RECResult(payload_bits=0, aux_bits=0, model="degree",
                        state=ans, aux=_degree_table(degrees))
        edges = rec_decode(res, n, m["n_edges"])
        g.adj_raw = _group_edges(edges, n)
    if r.version == 2 or "universes" not in r:
        g._universes = np.full(n, n, np.int64)
    else:
        runs = int(m["universe_runs"])
        flat = np.frombuffer(r.section("universes"), np.int64)
        g._universes = np.repeat(flat[:runs], flat[runs:])
    g._codec = get_codec(spec.ids)
    g._blobs = [g._codec.encode(a, int(u)) if len(a) else None
                for a, u in zip(g.adj_raw, g._universes)]
    g._decoded_cache = g._new_cache()
    return g


def _group_edges(edges: np.ndarray, n: int) -> List[np.ndarray]:
    """Lexicographically sorted (src, dst) rows -> per-node sorted adjacency."""
    counts = np.bincount(edges[:, 0], minlength=n) if edges.size else \
        np.zeros(n, np.int64)
    bounds = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return [edges[bounds[i]:bounds[i + 1], 1].astype(np.int64)
            for i in range(n)]


# ---------------------------------------------------------------------------
# file conveniences
# ---------------------------------------------------------------------------

def save_index(index, path: Optional[Union[str, os.PathLike]] = None,
               graph_codec: str = "webgraph") -> bytes:
    """Pack ``index``; also write the blob to ``path`` when given."""
    raw = pack_index(index, graph_codec=graph_codec)
    if path is not None:
        with open(path, "wb") as f:
            f.write(raw)
    return raw


def load_index(src: Union[bytes, str, os.PathLike]):
    """Load an index from a blob or a file path."""
    if isinstance(src, (bytes, bytearray)):
        return unpack_index(bytes(src))
    with open(src, "rb") as f:
        return unpack_index(f.read())
