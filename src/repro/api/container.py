"""RIDX v2 — one versioned container for *any* factory-built index.

Generalizes the v1 ``RIVF`` IVF-only blob (``repro.core.container``) to a
manifest-of-sections format whose manifest records the index's canonical
factory spec.  ``load_index(save_index(idx))`` returns an index whose
search results are **bit-identical** to the original:

* centroids / vectors / PQ codebooks are stored as exact f32 (the v1
  container's f16 centroids would perturb coarse probes);
* IVF id lists ride in one joint exact-ANS ROC stream (§4.3 offline
  setting, ``log n_k!`` collected per cluster);
* PQ codes go through the Pólya coder when the index carries one;
* graph edge lists go through the offline path — webgraph-lite by
  default, Random Edge Coding (``graph_codec="rec"``, static degree
  model + shipped degree table) on request;
* per-list online blobs (ROC/EF/...) and the wavelet tree are *not*
  stored: they are deterministic functions of (lists, universe) and are
  re-encoded on load, so ``id_bits()`` bookkeeping also round-trips.
"""

from __future__ import annotations

import os
from typing import List, Optional, Union

import numpy as np

from ..ann.graph import GraphIndex
from ..ann.ivf import IVFIndex
from ..ann.pq import ProductQuantizer
from ..core.ans import StreamANS
from ..core.codecs import get_codec
from ..core.container import (SectionReader, SectionWriter, pack_joint_ids,
                              pack_polya_sections, unpack_joint_ids,
                              unpack_polya_sections)
from ..core.polya import PolyaCodec
from ..core.rec import RECResult, _degree_table, rec_decode, rec_encode
from ..core.wavelet_tree import WaveletTree
from ..core.webgraph_lite import webgraph_decode, webgraph_encode
from .indexes import FlatIndex, GraphApiIndex, IVFApiIndex, as_api_index
from .spec import IndexSpec, parse_spec

__all__ = ["pack_index", "unpack_index", "save_index", "load_index",
           "wt_sequence", "RIDX_MAGIC", "RIDX_VERSION"]

RIDX_MAGIC = b"RIDX"
RIDX_VERSION = 2


def wt_sequence(lists: List[np.ndarray], n: int, nlist: int):
    """``(sequence, nsyms)`` for the wavelet tree over ``lists``.

    Monolithically the lists partition ``[0, n)`` and the sequence is the
    plain cluster-assignment string over ``nlist`` symbols (byte-identical
    to the pre-shard behaviour).  A planner-made cluster shard covers only
    part of the universe: absent ids map to the sentinel symbol ``nlist``
    (alphabet ``nlist + 1``), which no search ever selects on, so
    ``select(k, off)`` still returns *global* ids for every owned cluster.
    The rule is a pure function of ``(lists, n, nlist)`` — the planner and
    the RIDX loader apply it independently and agree, so ``id_bits()``
    bookkeeping round-trips through save/load for shards too.
    """
    seq = np.full(n, nlist, np.int64)
    for k, lst in enumerate(lists):
        if len(lst):
            seq[lst] = k
    covered = int(sum(len(lst) for lst in lists))
    return seq, (nlist if covered == n else nlist + 1)


# ---------------------------------------------------------------------------
# pack
# ---------------------------------------------------------------------------

def pack_index(index, graph_codec: str = "webgraph") -> bytes:
    """Serialize any factory-built (or raw IVF/Graph) index to one blob."""
    index = as_api_index(index)
    spec = parse_spec(index.spec)
    meta = {"spec": str(spec), "kind": spec.kind}
    w = SectionWriter()
    if isinstance(index, FlatIndex):
        meta.update(n=int(index.n), d=int(index.d))
        w.add("vecs", index.vecs.astype(np.float32).tobytes())
        id_map = getattr(index, "id_map", None)
        if id_map is not None:
            meta["id_map"] = True
            w.add("id_map", np.asarray(id_map, np.int64).tobytes())
    elif isinstance(index, IVFApiIndex):
        _pack_ivf_sections(w, meta, index.ivf)
    elif isinstance(index, GraphApiIndex):
        _pack_graph_sections(w, meta, index.graph, graph_codec)
    else:  # pragma: no cover - as_api_index guarantees one of the above
        raise TypeError(f"cannot pack {type(index).__name__}")
    return w.finish(RIDX_MAGIC, RIDX_VERSION, meta)


def _pack_ivf_sections(w: SectionWriter, meta: dict, ivf: IVFIndex) -> None:
    meta.update(n=int(ivf.n), d=int(ivf.d), nlist=int(ivf.nlist))
    w.add("sizes", ivf.sizes.astype(np.int64).tobytes())
    w.add("centroids", ivf.centroids.astype(np.float32).tobytes())
    w.add("ids", pack_joint_ids(ivf._lists, ivf.n))
    meta["pq"] = ({"m": int(ivf.pq.m), "bits": int(ivf.pq.bits)}
                  if ivf.pq is not None else None)
    if ivf.pq is not None:
        w.add("pq_codebooks", ivf.pq.codebooks.astype(np.float32).tobytes())
    if getattr(ivf, "_code_blob", None) is not None:
        meta["code"] = pack_polya_sections(w, ivf._code_blob)
    elif ivf.codes is not None:
        w.add("codes_raw", ivf.codes.tobytes())
        meta["code"] = {"m": int(ivf.codes.shape[1]), "raw": True}
    else:
        meta["code"] = None
        w.add("vecs", ivf.vecs.astype(np.float32).tobytes())


def _pack_graph_sections(w: SectionWriter, meta: dict, g: GraphIndex,
                         graph_codec: str) -> None:
    meta.update(n=int(g.n), d=int(g.x.shape[1]), entry=int(g.entry),
                graph_codec=graph_codec)
    w.add("vecs", g.x.astype(np.float32).tobytes())
    id_map = getattr(g, "id_map", None)
    if id_map is not None:
        meta["id_map"] = True
        w.add("id_map", np.asarray(id_map, np.int64).tobytes())
    if graph_codec == "webgraph":
        ans = webgraph_encode(g.adj_raw, g.n)
        head, tail = ans.tobytes()
        w.add("graph_head", head)
        w.add("graph_tail", tail)
    elif graph_codec == "rec":
        edges = _edge_list(g.adj_raw)
        meta["n_edges"] = int(edges.shape[0])
        res = rec_encode(edges, g.n, model="degree")
        head, tail = res.state.tobytes()
        w.add("graph_head", head)
        w.add("graph_tail", tail)
        degrees = np.bincount(edges.reshape(-1), minlength=g.n)
        w.add("degrees", degrees.astype(np.int64).tobytes())
    else:
        raise ValueError(f"unknown graph_codec {graph_codec!r} "
                         "(options: webgraph, rec)")


def _edge_list(adj: List[np.ndarray]) -> np.ndarray:
    src = np.concatenate([np.full(len(a), i, np.int64)
                          for i, a in enumerate(adj)] or
                         [np.zeros(0, np.int64)])
    dst = (np.concatenate(adj) if any(len(a) for a in adj)
           else np.zeros(0, np.int64))
    return np.stack([src.astype(np.int64), dst.astype(np.int64)], axis=1)


# ---------------------------------------------------------------------------
# unpack
# ---------------------------------------------------------------------------

def unpack_index(raw: bytes):
    """Inverse of :func:`pack_index`: a ready-to-search api index."""
    r = SectionReader(raw, RIDX_MAGIC)
    if r.version != RIDX_VERSION:
        raise ValueError(f"unsupported RIDX version {r.version}")
    m = r.manifest
    spec = parse_spec(m["spec"])
    if spec.kind == "flat":
        idx = FlatIndex(spec)
        idx.n, idx.d = m["n"], m["d"]
        idx.vecs = _f32(r.section("vecs"), (m["n"], m["d"]))
        if m.get("id_map"):
            idx.id_map = np.frombuffer(r.section("id_map"), np.int64).copy()
        return idx
    if spec.kind == "ivf":
        return IVFApiIndex.from_built(_unpack_ivf(r, spec), spec)
    return GraphApiIndex.from_built(_unpack_graph(r, spec), spec)


def _f32(raw: bytes, shape) -> np.ndarray:
    return np.frombuffer(raw, np.float32).reshape(shape).copy()


def _unpack_ivf(r: SectionReader, spec: IndexSpec) -> IVFIndex:
    m = r.manifest
    n, d, nlist = m["n"], m["d"], m["nlist"]
    pq = None
    if m["pq"]:
        pq = ProductQuantizer(m=m["pq"]["m"], bits=m["pq"]["bits"])
        pq.codebooks = _f32(r.section("pq_codebooks"),
                            (pq.m, pq.ksub, d // pq.m))
    ivf = IVFIndex(nlist=nlist, id_codec=spec.ids, pq=pq,
                   code_codec=spec.codes,
                   cache_bytes=(int(spec.cache_mb * (1 << 20))
                                if spec.cache_mb is not None else None))
    ivf.n, ivf.d = n, d
    ivf.sizes = np.frombuffer(r.section("sizes"), np.int64).copy()
    ivf.offsets = np.concatenate([[0], np.cumsum(ivf.sizes)]).astype(np.int64)
    ivf.centroids = _f32(r.section("centroids"), (nlist, d))
    ivf._lists = unpack_joint_ids(r.section("ids"), ivf.sizes, n)
    # assignment string (id -> cluster); also the storage permutation source
    ivf.cluster_of = np.zeros(n, np.int32)
    if n:
        ivf.cluster_of[np.concatenate(ivf._lists)] = np.repeat(
            np.arange(nlist, dtype=np.int32), ivf.sizes)
    # payload (cluster-grouped storage order)
    cm = m["code"]
    if cm is None:
        ivf.codes = None
        # shards store fewer rows than the global universe n
        ivf.vecs = _f32(r.section("vecs"), (int(ivf.sizes.sum()), d))
        ivf._code_blob = None
    elif cm.get("raw"):
        ivf.vecs = None
        ivf.codes = np.frombuffer(r.section("codes_raw"), np.uint8).reshape(
            -1, cm["m"]).copy()
        ivf._code_blob = None
    else:
        ivf.vecs = None
        blob = unpack_polya_sections(r, [int(s) for s in ivf.sizes], cm)
        per = PolyaCodec().decode(blob)
        ivf.codes = np.concatenate(per, axis=0)
        ivf._code_blob = blob
        ivf._polya = PolyaCodec()
    # online id structures: deterministic re-encode from the decoded lists,
    # so size_bits bookkeeping matches the pre-save index exactly
    if spec.ids in ("wt", "wt1"):
        seq, nsyms = wt_sequence(ivf._lists, n, nlist)
        ivf._wt = WaveletTree.build(seq, nsyms,
                                    compressed=(spec.ids == "wt1"))
        ivf._blobs = None
    else:
        ivf._wt = None
        ivf._codec = get_codec(spec.ids)
        ivf._blobs = [ivf._codec.encode(lst, n) for lst in ivf._lists]
    ivf._decoded_cache = ivf._new_cache()
    return ivf


def _unpack_graph(r: SectionReader, spec: IndexSpec) -> GraphIndex:
    m = r.manifest
    n, d = m["n"], m["d"]
    g = GraphIndex(id_codec=spec.ids,
                   cache_bytes=(int(spec.cache_mb * (1 << 20))
                                if spec.cache_mb is not None else None))
    g.n = n
    g.x = _f32(r.section("vecs"), (n, d))
    g.entry = int(m["entry"])
    if m.get("id_map"):
        g.id_map = np.frombuffer(r.section("id_map"), np.int64).copy()
    if m["graph_codec"] == "webgraph":
        ans = StreamANS.frombytes(r.section("graph_head"),
                                  r.section("graph_tail"))
        g.adj_raw = [a.astype(np.int64) for a in webgraph_decode(ans, n, n)]
    else:  # rec
        degrees = np.frombuffer(r.section("degrees"), np.int64)
        ans = StreamANS.frombytes(r.section("graph_head"),
                                  r.section("graph_tail"))
        res = RECResult(payload_bits=0, aux_bits=0, model="degree",
                        state=ans, aux=_degree_table(degrees))
        edges = rec_decode(res, n, m["n_edges"])
        g.adj_raw = _group_edges(edges, n)
    g._codec = get_codec(spec.ids)
    g._blobs = [g._codec.encode(a, n) if len(a) else None for a in g.adj_raw]
    g._decoded_cache = g._new_cache()
    return g


def _group_edges(edges: np.ndarray, n: int) -> List[np.ndarray]:
    """Lexicographically sorted (src, dst) rows -> per-node sorted adjacency."""
    counts = np.bincount(edges[:, 0], minlength=n) if edges.size else \
        np.zeros(n, np.int64)
    bounds = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return [edges[bounds[i]:bounds[i + 1], 1].astype(np.int64)
            for i in range(n)]


# ---------------------------------------------------------------------------
# file conveniences
# ---------------------------------------------------------------------------

def save_index(index, path: Optional[Union[str, os.PathLike]] = None,
               graph_codec: str = "webgraph") -> bytes:
    """Pack ``index``; also write the blob to ``path`` when given."""
    raw = pack_index(index, graph_codec=graph_codec)
    if path is not None:
        with open(path, "wb") as f:
            f.write(raw)
    return raw


def load_index(src: Union[bytes, str, os.PathLike]):
    """Load an index from a blob or a file path."""
    if isinstance(src, (bytes, bytearray)):
        return unpack_index(bytes(src))
    with open(src, "rb") as f:
        return unpack_index(f.read())
