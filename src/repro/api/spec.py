"""Factory spec strings — the faiss ``index_factory`` idea for this repo.

One comma-separated string names an index structure, its payload coding
and its id coding, so benchmarks/services can sweep the whole
codec × structure matrix from a single ``--spec`` flag::

    spec   := struct ("," pq)? ("," key "=" value)*
    struct := "Flat" | "IVF" <nlist> | "NSG" <R> | "HNSW" <M>
    pq     := "PQ" <m> ("x" <bits>)?          # IVF only
    keys   := ids          = unc64|unc32|compact|ef|roc|gap_ans|wt|wt1
              codes        = polya            # IVF+PQ only
              cache_mb     = <float>          # DecodedListCache budget
              cache_policy = lru|2q           # DecodedListCache eviction
              max_epochs   = <int>            # auto-compact ingest threshold
              engine       = auto|xla|pallas  # scan backend (IVF + graph)

``ids=wt|wt1`` (the joint wavelet tree) applies only to IVF — friend
lists are not a partition.  ``cache_policy``/``max_epochs`` apply to the
structures that own a decode cache / take online ingest (IVF + graph,
not Flat).  :func:`parse_spec` accepts options in any order;
:meth:`IndexSpec.__str__` emits the canonical form (struct, PQ, ids,
codes, cache_mb, cache_policy, max_epochs, engine) so canonical strings
round-trip exactly: ``str(parse_spec(s)) == s``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from ..core.codecs import CODEC_NAMES

__all__ = ["IndexSpec", "parse_spec", "KNOWN_OPTION_KEYS"]

#: every ``key=value`` option :func:`parse_spec` accepts, in canonical
#: emission order.  The grammar block in ``docs/architecture.md`` must
#: list exactly these keys — analysis rule RPA007 fails on drift.
KNOWN_OPTION_KEYS = ("ids", "codes", "cache_mb", "cache_policy",
                     "max_epochs", "engine")

_WT_NAMES = ("wt", "wt1")
_ID_NAMES = tuple(CODEC_NAMES) + _WT_NAMES
_ENGINES = ("auto", "xla", "pallas")
_CACHE_POLICIES = ("lru", "2q")
_STRUCT_RE = re.compile(r"^(Flat|IVF|NSG|HNSW)(\d+)?$")
_PQ_RE = re.compile(r"^PQ(\d+)(?:x(\d+))?$")


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Parsed, canonical form of one factory string."""

    kind: str                         # "flat" | "ivf" | "nsg" | "hnsw"
    nlist: int = 0                    # IVF cluster count
    degree: int = 0                   # NSG R / HNSW M
    pq_m: int = 0                     # 0 = flat vectors
    pq_bits: int = 8
    ids: str = "roc"                  # id codec ("" for Flat)
    codes: Optional[str] = None       # None | "polya"
    cache_mb: Optional[float] = None  # DecodedListCache budget
    cache_policy: Optional[str] = None  # None (= "lru") | "lru" | "2q"
    max_epochs: Optional[int] = None  # compact once ingest exceeds this
    engine: Optional[str] = None      # scan backend, IVF + graph (None = "auto")

    def __post_init__(self) -> None:
        if self.kind not in ("flat", "ivf", "nsg", "hnsw"):
            raise ValueError(f"unknown index kind {self.kind!r}")
        if self.kind == "ivf" and self.nlist <= 0:
            raise ValueError("IVF needs a positive nlist (e.g. 'IVF1024')")
        if self.kind in ("nsg", "hnsw") and self.degree <= 0:
            raise ValueError(f"{self.kind.upper()} needs a positive degree")
        if self.kind == "flat":
            # "roc" is the untouched dataclass default; anything else was
            # explicitly requested and is an error on Flat
            if self.pq_m or self.codes or self.ids not in ("", "roc"):
                raise ValueError("Flat takes no PQ/ids/codes options")
            object.__setattr__(self, "ids", "")
        else:
            if self.ids not in _ID_NAMES:
                raise ValueError(
                    f"unknown id codec {self.ids!r}; options: {_ID_NAMES}")
        if self.kind in ("nsg", "hnsw"):
            if self.ids in _WT_NAMES:
                raise ValueError(
                    "ids=wt/wt1 is a joint structure over an IVF partition; "
                    "graph friend lists must use a per-list codec")
            if self.pq_m or self.codes:
                raise ValueError("graph indexes store flat vectors "
                                 "(no PQ/codes options)")
        if self.codes is not None:
            if self.codes != "polya":
                raise ValueError(f"unknown code codec {self.codes!r}")
            if not self.pq_m:
                raise ValueError("codes=polya requires a PQ token")
        if self.pq_m and self.pq_bits != 8:
            raise ValueError("only 8-bit PQ is supported (PQmx8)")
        if self.engine is not None and self.engine not in _ENGINES:
            raise ValueError(
                f"unknown engine {self.engine!r}; options: {_ENGINES}")
        if self.cache_mb is not None and self.cache_mb <= 0:
            raise ValueError("cache_mb must be positive")
        if self.cache_policy is not None:
            if self.cache_policy not in _CACHE_POLICIES:
                raise ValueError(f"unknown cache_policy "
                                 f"{self.cache_policy!r}; "
                                 f"options: {_CACHE_POLICIES}")
            if self.kind == "flat":
                raise ValueError("Flat has no decode cache "
                                 "(cache_policy does not apply)")
        if self.max_epochs is not None:
            if self.max_epochs <= 0:
                raise ValueError("max_epochs must be positive")
            if self.kind == "flat":
                raise ValueError("Flat ingest has no epochs "
                                 "(max_epochs does not apply)")

    def __str__(self) -> str:
        if self.kind == "flat":
            parts = ["Flat"]
        elif self.kind == "ivf":
            parts = [f"IVF{self.nlist}"]
        else:
            parts = [f"{self.kind.upper()}{self.degree}"]
        if self.pq_m:
            parts.append(f"PQ{self.pq_m}x{self.pq_bits}")
        if self.kind != "flat":
            parts.append(f"ids={self.ids}")
        if self.codes:
            parts.append(f"codes={self.codes}")
        if self.cache_mb is not None:
            mb = self.cache_mb
            parts.append(f"cache_mb={int(mb) if mb == int(mb) else mb}")
        if self.cache_policy is not None:
            parts.append(f"cache_policy={self.cache_policy}")
        if self.max_epochs is not None:
            parts.append(f"max_epochs={self.max_epochs}")
        if self.engine is not None:
            parts.append(f"engine={self.engine}")
        return ",".join(parts)


def parse_spec(spec: str) -> IndexSpec:
    """Parse a factory string into an :class:`IndexSpec` (see module doc)."""
    if isinstance(spec, IndexSpec):
        return spec
    tokens = [t.strip() for t in str(spec).split(",") if t.strip()]
    if not tokens:
        raise ValueError("empty index spec")
    m = _STRUCT_RE.match(tokens[0])
    if not m or (m.group(1) == "Flat") != (m.group(2) is None):
        raise ValueError(
            f"bad structure token {tokens[0]!r} "
            "(expected Flat, IVF<nlist>, NSG<R> or HNSW<M>)")
    struct, num = m.group(1), int(m.group(2) or 0)
    kw = dict(kind=struct.lower(), nlist=0, degree=0, pq_m=0, pq_bits=8,
              ids="" if struct == "Flat" else "roc", codes=None,
              cache_mb=None, cache_policy=None, max_epochs=None, engine=None)
    if struct == "IVF":
        kw["nlist"] = num
    elif struct in ("NSG", "HNSW"):
        kw["degree"] = num
    seen = set()
    for tok in tokens[1:]:
        pm = _PQ_RE.match(tok)
        if pm:
            if "pq" in seen:
                raise ValueError("duplicate PQ token")
            if struct != "IVF":
                raise ValueError(f"PQ token is only valid on IVF, got {tok!r} "
                                 f"on {struct}")
            seen.add("pq")
            kw["pq_m"] = int(pm.group(1))
            kw["pq_bits"] = int(pm.group(2) or 8)
            continue
        if "=" not in tok:
            raise ValueError(f"bad spec token {tok!r}")
        key, val = tok.split("=", 1)
        if key in seen:
            raise ValueError(f"duplicate option {key!r}")
        seen.add(key)
        if key == "ids":
            kw["ids"] = val
        elif key == "codes":
            kw["codes"] = val
        elif key == "cache_mb":
            kw["cache_mb"] = float(val)
        elif key == "cache_policy":
            kw["cache_policy"] = val
        elif key == "max_epochs":
            kw["max_epochs"] = int(val)
        elif key == "engine":
            kw["engine"] = val
        else:
            raise ValueError(f"unknown spec option {key!r} "
                             f"(known: {', '.join(KNOWN_OPTION_KEYS)})")
    return IndexSpec(**kw)
