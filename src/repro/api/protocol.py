"""The one index contract every front door implements.

``repro.api`` exposes IVF, graph and flat indexes through a single
protocol so services (``repro.serve.AnnService``), benchmarks and the
retrieval side-car can hold *any* index:

* ``build(x)`` — construct from a vector matrix, returns self.
* ``add(x)`` — append vectors to a built index (ids continue upward).
* ``search(queries, k, **opts) -> (dists, ids, stats)`` — faiss D/I
  order; ``stats`` is a :class:`repro.ann.stats.SearchStats` whatever
  the structure.  Per-structure knobs ride in ``opts`` (IVF: ``nprobe``,
  ``engine``, ``query_block``; graph: ``ef``).
* ``memory_ledger()`` — bytes by component plus uncompressed baselines.
* ``spec`` — the canonical factory string; ``index_factory(idx.spec)``
  reconstructs an equivalent empty index.
"""

from __future__ import annotations

from typing import Any, Dict, Protocol, Tuple, runtime_checkable

import numpy as np

from ..ann.stats import SearchStats

__all__ = ["Index", "IvfBacked"]


@runtime_checkable
class Index(Protocol):
    """Structural type of every factory-built index."""

    @property
    def spec(self) -> str: ...

    def build(self, x: np.ndarray) -> "Index": ...

    def add(self, x: np.ndarray) -> "Index": ...

    def search(self, queries: np.ndarray, k: int = 10, **opts: Any
               ) -> Tuple[np.ndarray, np.ndarray, SearchStats]: ...

    def memory_ledger(self) -> Dict[str, float]: ...


@runtime_checkable
class IvfBacked(Protocol):
    """An api index backed by a core ``IVFIndex`` (exposes ``.ivf``).

    The sharded router keys merge behaviour on this: IVF shards return
    ``(probe_rank << 40) | offset`` merge keys, flat/graph shards merge
    by global id.  Checking the protocol (instead of ``hasattr`` on the
    hot path) keeps the seam explicit — see RPA001 in ``repro.analysis``.
    """

    @property
    def ivf(self) -> Any: ...
