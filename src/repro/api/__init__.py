"""repro.api — the unified index layer.

One :class:`Index` protocol, faiss-style factory strings, and lossless
save/load for every index type::

    from repro.api import index_factory, save_index, load_index

    idx = index_factory("IVF1024,PQ8x8,ids=roc,codes=polya").build(x)
    dists, ids, stats = idx.search(queries, k=10)
    blob = save_index(idx)                 # RIDX container (v3 writer)
    idx2 = load_index(blob)                # bit-identical search results

Spec grammar: see :mod:`repro.api.spec` (and ROADMAP.md).  Everything a
consumer needs — building, serving (``repro.serve.AnnService``), sizing
(``memory_ledger``), persistence — goes through this seam.
"""

from .container import (load_index, pack_index, save_index, unpack_index)
from .indexes import (FlatIndex, GraphApiIndex, IVFApiIndex, as_api_index,
                      make_index)
from .protocol import Index
from .spec import IndexSpec, parse_spec

__all__ = [
    "Index", "IndexSpec", "parse_spec", "index_factory", "as_api_index",
    "FlatIndex", "IVFApiIndex", "GraphApiIndex",
    "pack_index", "unpack_index", "save_index", "load_index",
]


def index_factory(spec) -> Index:
    """Factory-string (or :class:`IndexSpec`) -> empty index; ``.build(x)`` it.

    >>> index_factory("IVF64,ids=roc").spec
    'IVF64,ids=roc'
    """
    return make_index(spec)
