"""Concrete :class:`repro.api.Index` implementations.

Thin adapters presenting the repo's index structures through the one
protocol (faiss ``(dists, ids)`` order, uniform :class:`SearchStats`,
uniform memory ledger):

* :class:`FlatIndex`   — exact brute-force baseline (no compression).
* :class:`IVFApiIndex` — wraps :class:`repro.ann.ivf.IVFIndex` (all id
  codecs + wavelet tree, optional PQ / Pólya codes).
* :class:`GraphApiIndex` — wraps :class:`repro.ann.graph.GraphIndex`
  with the NSG/HNSW builders (per-list id codec choice).

``as_api_index`` upgrades a raw ``IVFIndex``/``GraphIndex`` so existing
call sites (e.g. ``AnnService(IVFIndex(...).build(x))``) keep working.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from ..ann.graph import GraphIndex, build_hnsw, build_nsg
from ..ann.ivf import IVFIndex
from ..ann.pq import ProductQuantizer
from ..ann.scan import score_rows_flat, select_topk
from ..ann.stats import SearchStats
from .protocol import Index
from .spec import IndexSpec, parse_spec

__all__ = ["FlatIndex", "IVFApiIndex", "GraphApiIndex", "as_api_index"]


def _cache_bytes(spec: IndexSpec) -> Optional[int]:
    if spec.cache_mb is None:
        return None
    return int(spec.cache_mb * (1 << 20))


def _ingest_fields(spec: IndexSpec) -> dict:
    """Constructor kwargs shared by the IVF and graph inner indexes."""
    return dict(cache_bytes=_cache_bytes(spec),
                cache_policy=spec.cache_policy or "lru",
                max_epochs=spec.max_epochs)


class _SpecMixin:
    index_spec: IndexSpec

    @property
    def spec(self) -> str:
        """Canonical factory string (``index_factory(idx.spec)`` rebuilds)."""
        return str(self.index_spec)

    def __repr__(self) -> str:  # pragma: no cover
        n = getattr(self, "n", None)
        return f"{type(self).__name__}(spec={self.spec!r}, n={n})"


class FlatIndex(_SpecMixin):
    """Exact brute-force search over raw f32 vectors (the recall oracle).

    ``id_map`` (set by the shard planner, serialized in the RIDX
    container) remaps
    local row indices to global database ids: a hash-partitioned shard
    holds a row subset but still answers with the unsharded id space.
    Rows are kept in ascending global-id order, so the stable local
    tie-break (smaller row first) coincides with the monolithic one
    (smaller id first) and sharded merges stay bit-identical.
    """

    def __init__(self, spec: Optional[IndexSpec] = None):
        self.index_spec = spec or IndexSpec(kind="flat")
        self.id_map: Optional[np.ndarray] = None

    def build(self, x: np.ndarray, seed: int = 0) -> "FlatIndex":
        """Store ``x`` as the (n, d) f32 base matrix; no trained state."""
        del seed  # no trained state; accepted for protocol uniformity
        self.vecs = np.asarray(x, np.float32)
        self.n, self.d = self.vecs.shape
        return self

    def add(self, x: np.ndarray) -> "FlatIndex":
        """Append rows (dense ids ``n..n+m-1``); planner shards must route
        ingest through :meth:`append_rows` instead."""
        if getattr(self, "id_map", None) is not None:
            raise ValueError("cannot add() to a planner-made Flat shard: "
                             "its global-id mapping is fixed by the plan")
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None]
        self.vecs = np.concatenate([self.vecs, x], axis=0)
        self.n = self.vecs.shape[0]
        return self

    def append_rows(self, x: np.ndarray,
                    global_ids: np.ndarray) -> "FlatIndex":
        """Routed ingest for a planner-made shard: append the owned rows
        and extend ``id_map``.  New global ids exceed every existing one,
        so ascending order (the sharded tie-break invariant) is kept."""
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None]
        global_ids = np.asarray(global_ids, np.int64)
        if x.shape[0] != global_ids.shape[0]:
            raise ValueError("one global id per appended row")
        if x.shape[0] == 0:
            return self
        if self.id_map is None:
            if np.any(global_ids != self.n + np.arange(global_ids.size)):
                raise ValueError("unsharded Flat ingest must be dense "
                                 "(ids n..n+m-1); use add()")
            self.vecs = np.concatenate([self.vecs, x], axis=0)
            self.n = self.vecs.shape[0]
            return self
        if self.id_map.size and int(global_ids[0]) <= int(self.id_map[-1]):
            raise ValueError("appended global ids must exceed existing ones")
        self.vecs = np.concatenate([self.vecs, x], axis=0)
        self.n = self.vecs.shape[0]
        self.id_map = np.concatenate([self.id_map, global_ids])
        return self

    def search(self, queries: np.ndarray, k: int = 10,
               engine: Optional[str] = None, query_block: int = 64, **opts):
        """Exact k-NN.  ``engine`` (or ``Flat,engine=...`` in the spec)
        routes scoring through the batched kernel path
        (``repro.ann.scan.batched_flat_search``: Pallas/XLA scoring +
        device-side segmented top-k); without one, the legacy per-query
        numpy loop runs.  Results are bit-identical either way — the
        kernel path re-scores its short-list with the same scalar numpy
        expression — only ``stats.engine`` and the select counters tell
        them apart."""
        if opts:
            raise TypeError(f"FlatIndex.search got unknown options {sorted(opts)}")
        engine = engine or self.index_spec.engine
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        if engine is not None:
            from ..ann.scan import batched_flat_search

            ids, dists, stats = batched_flat_search(
                self.vecs, queries, topk=k, engine=engine,
                query_block=query_block)
        else:
            t0 = time.perf_counter()
            k_eff = min(k, self.n)
            ids = np.zeros((nq, k), np.int64)
            dists = np.full((nq, k), np.inf, np.float32)
            # scalar numpy scoring per query: deterministic, stable ties —
            # the same path the IVF oracle uses, so results are
            # reproducible bit-wise
            for qi in range(nq):
                d = score_rows_flat(self.vecs, queries[qi])
                sel = select_topk(d, k_eff)
                ids[qi, :k_eff] = sel
                dists[qi, :k_eff] = d[sel]
            stats = SearchStats(wall_s=time.perf_counter() - t0,
                                ndis=self.n * nq, id_resolve_s=0.0,
                                engine="flat")
        id_map = getattr(self, "id_map", None)
        if id_map is not None:
            # remap valid slots only: padding must stay id 0 / dist inf
            ids = np.where(np.isfinite(dists), id_map[ids], 0)
        return dists, ids, stats

    def memory_ledger(self) -> Dict[str, float]:
        """Bytes by component (vectors + optional id_map); flat stores no
        compressed ids, so all three id layouts coincide."""
        id_map = getattr(self, "id_map", None)
        map_bytes = float(id_map.nbytes) if id_map is not None else 0.0
        return {
            "n": self.n,
            "ids_bytes": map_bytes,
            "ids_bytes_unc64": map_bytes,
            "ids_bytes_compact": map_bytes,
            "payload_bytes": float(self.vecs.nbytes),
            "payload_bytes_unc": float(self.vecs.nbytes),
            "centroid_bytes": 0.0,
            "decoded_cache_bytes": 0.0,
            "total_bytes": float(self.vecs.nbytes) + map_bytes,
        }


class IVFApiIndex(_SpecMixin):
    """Protocol adapter over the batched compressed-IVF index."""

    def __init__(self, spec: IndexSpec):
        self.index_spec = spec
        pq = (ProductQuantizer(m=spec.pq_m, bits=spec.pq_bits)
              if spec.pq_m else None)
        self.ivf = IVFIndex(nlist=spec.nlist, id_codec=spec.ids, pq=pq,
                            code_codec=spec.codes, **_ingest_fields(spec))

    @classmethod
    def from_built(cls, ivf: IVFIndex,
                   spec: Optional[IndexSpec] = None) -> "IVFApiIndex":
        self = cls.__new__(cls)
        policy = getattr(ivf, "cache_policy", None)
        self.index_spec = spec or IndexSpec(
            kind="ivf", nlist=ivf.nlist, ids=ivf.id_codec,
            pq_m=ivf.pq.m if ivf.pq else 0, codes=ivf.code_codec,
            cache_mb=(ivf.cache_bytes / (1 << 20)
                      if getattr(ivf, "cache_bytes", None) else None),
            cache_policy=None if policy in (None, "lru") else policy,
            max_epochs=getattr(ivf, "max_epochs", None))
        self.ivf = ivf
        return self

    @property
    def n(self) -> int:
        """Size of the id universe (global row count, not rows held)."""
        return self.ivf.n

    def build(self, x: np.ndarray, seed: int = 0,
              centroids: Optional[np.ndarray] = None) -> "IVFApiIndex":
        """Train + populate the inner :class:`IVFIndex` (k-means coarse
        quantizer unless ``centroids`` is given; one sealed epoch)."""
        self.ivf.build(np.asarray(x, np.float32), seed=seed,
                       centroids=centroids)
        return self

    def add(self, x: np.ndarray) -> "IVFApiIndex":
        """Append rows as one new epoch (dense ids ``n..n+m-1``)."""
        self.ivf.add(x)
        return self

    def append_rows(self, x: np.ndarray, global_ids: np.ndarray,
                    count: Optional[int] = None) -> "IVFApiIndex":
        """Routed ingest: seal the epoch holding these (possibly partial)
        rows.  A cluster shard passes only its owned rows plus the global
        epoch ``count`` so epoch boundaries stay universe-wide; see
        :meth:`IVFIndex.append_epoch`."""
        global_ids = np.asarray(global_ids, np.int64)
        if count is None:
            count = (int(global_ids.max()) + 1 - self.ivf.n
                     if global_ids.size else 0)
        if count > 0:
            self.ivf.append_epoch(x, global_ids, count)
        return self

    def compact(self) -> "IVFApiIndex":
        """Fold all epochs back into one (recovers single-universe rates)."""
        self.ivf.compact()
        return self

    @property
    def n_epochs(self) -> int:
        """Number of sealed ingest epochs currently stored."""
        return self.ivf.n_epochs

    def search(self, queries: np.ndarray, k: int = 10, nprobe: int = 16,
               engine: Optional[str] = None, query_block: int = 64,
               with_keys: bool = False, select: str = "auto",
               select_min: Optional[int] = None):
        """Compressed-domain IVF search (faiss ``(dists, ids)`` order).

        ``nprobe`` lists are ranked per query; ``engine`` picks the
        scoring kernel (``auto``/``xla``/``pallas``) and ``select`` where
        the top-k short-list is cut (``host``/``device``/``auto``) — all
        bit-identical, see :mod:`repro.ann.scan`."""
        ids, dists, stats = self.ivf.search(
            np.asarray(queries, np.float32), nprobe=nprobe, topk=k,
            engine=engine or self.index_spec.engine or "auto",
            query_block=query_block, with_keys=with_keys, select=select,
            select_min=select_min)
        return dists, ids, stats

    def memory_ledger(self) -> Dict[str, float]:
        """Bytes by component: compressed ids vs the uncompressed-64 and
        ceil(log2 n) baselines, payload (PQ/Pólya or raw), centroids,
        decoded-list cache."""
        idx = self.ivf
        # vectors actually held: == n monolithically, < n for a planner-made
        # cluster shard (whose id universe stays the global n)
        n = int(idx.sizes.sum())
        id_bytes = idx.id_bits() / 8.0
        if idx.codes is not None:
            payload = idx.codes.shape[1] * n * idx.code_bits_per_element() / 8.0
            payload_unc = idx.codes.nbytes
        else:
            payload = payload_unc = idx.vecs.nbytes
        cache = idx.decoded_cache.stats()
        return {
            "n": n,
            "epochs": float(idx.n_epochs),
            "ids_bytes": id_bytes,
            "ids_bytes_unc64": 8.0 * n,
            "ids_bytes_compact": float(np.ceil(np.log2(max(2, idx.n)))) * n / 8.0,
            "payload_bytes": payload,
            "payload_bytes_unc": payload_unc,
            "centroid_bytes": idx.centroids.nbytes,
            "decoded_cache_bytes": cache["bytes"],
            "total_bytes": id_bytes + payload + idx.centroids.nbytes
            + cache["bytes"],
        }


class GraphApiIndex(_SpecMixin):
    """Protocol adapter over the NSG/HNSW graph index."""

    def __init__(self, spec: IndexSpec):
        self.index_spec = spec
        self.graph = GraphIndex(id_codec=spec.ids, **_ingest_fields(spec))

    @classmethod
    def from_built(cls, graph: GraphIndex,
                   spec: Optional[IndexSpec] = None) -> "GraphApiIndex":
        self = cls.__new__(cls)
        # a raw GraphIndex doesn't know its builder; default the spec to NSG
        # with the observed degree cap (callers with the truth pass `spec`)
        self.index_spec = spec or IndexSpec(
            kind="nsg", degree=max((len(a) for a in graph.adj_raw), default=1),
            ids=graph.id_codec)
        self.graph = graph
        return self

    @property
    def n(self) -> int:
        """Size of the id universe (global row count, not rows held)."""
        return self.graph.n

    def build(self, x: np.ndarray, seed: int = 0,
              adj: Optional[List[np.ndarray]] = None) -> "GraphApiIndex":
        """Build the NSG/HNSW adjacency for ``x`` (or take ``adj`` as
        given) and compress it per list with the spec's id codec."""
        x = np.asarray(x, np.float32)
        if adj is None:
            builder = build_nsg if self.index_spec.kind == "nsg" else build_hnsw
            adj = builder(x, self.index_spec.degree, seed=seed)
        self.graph.build(x, adj)
        return self

    def add(self, x: np.ndarray) -> "GraphApiIndex":
        """Append rows as a new epoch, wiring them into the graph with
        degree-capped greedy edges (dense ids ``n..n+m-1``)."""
        if getattr(self.graph, "id_map", None) is not None:
            raise ValueError("cannot add() to a planner-made graph shard: "
                             "its global-id mapping is fixed by the plan; "
                             "route ingest through append_rows()")
        self.graph.add(x, r=self.index_spec.degree)
        return self

    def append_rows(self, x: np.ndarray,
                    global_ids: np.ndarray) -> "GraphApiIndex":
        """Routed ingest for a planner-made shard: insert the rows this
        shard owns and extend ``id_map``.  New global ids exceed every
        existing one, so the map stays ascending and the sharded-merge
        tie order stays aligned with the monolithic one."""
        x = np.asarray(x, np.float32).reshape(-1, self.graph.x.shape[1])
        global_ids = np.asarray(global_ids, np.int64)
        if x.shape[0] != global_ids.shape[0]:
            raise ValueError("one global id per appended row")
        if x.shape[0] == 0:
            return self
        id_map = getattr(self.graph, "id_map", None)
        if id_map is None:
            if np.any(global_ids != self.graph.n
                      + np.arange(global_ids.size)):
                raise ValueError("unsharded graph ingest must be dense "
                                 "(ids n..n+m-1); use add()")
            self.graph.add(x, r=self.index_spec.degree)
            return self
        if global_ids.size and int(global_ids[0]) <= int(id_map[-1]):
            raise ValueError("appended global ids must exceed existing ones")
        self.graph.add(x, r=self.index_spec.degree)
        self.graph.id_map = np.concatenate([id_map, global_ids])
        return self

    def compact(self) -> "GraphApiIndex":
        """Fold all epochs back into one (recovers single-universe rates)."""
        self.graph.compact()
        return self

    @property
    def n_epochs(self) -> int:
        """Number of sealed ingest epochs currently stored."""
        return self.graph.n_epochs

    def search(self, queries: np.ndarray, k: int = 10,
               ef: Optional[int] = None, engine: Optional[str] = None,
               query_block: int = 64, select: str = "auto"):
        """Beam (best-first) graph search with compressed adjacency.

        ``ef`` is the beam width (default ``max(16, 2k)``); ``engine``
        picks the distance kernel and ``select`` whether the per-step
        candidate distance is gathered on device — bit-identical either
        way, see :mod:`repro.ann.graph_scan`."""
        ids, dists, stats = self.graph.search(
            np.asarray(queries, np.float32),
            ef=ef if ef is not None else max(16, 2 * k), topk=k,
            engine=engine or self.index_spec.engine or "auto",
            query_block=query_block, select=select)
        id_map = getattr(self.graph, "id_map", None)
        if id_map is not None:
            # shard planner remap (local node -> global id); padding slots
            # (dist inf) must stay id 0, matching the monolithic convention
            ids = np.where(np.isfinite(dists), id_map[ids], 0)
        return dists, ids, stats

    def memory_ledger(self) -> Dict[str, float]:
        """Bytes by component: compressed adjacency ids vs uncompressed-64
        and ceil(log2 n) baselines, raw vectors, decoded-list cache."""
        g = self.graph
        edges = sum(len(a) for a in g.adj_raw)
        id_bytes = g.id_bits() / 8.0
        id_map = getattr(g, "id_map", None)
        map_bytes = float(id_map.nbytes) if id_map is not None else 0.0
        cache = g.decoded_cache.stats()
        return {
            "n": g.n,
            "epochs": float(g.n_epochs),
            "edges": edges,
            "ids_bytes": id_bytes + map_bytes,
            "ids_bytes_unc64": 8.0 * edges + map_bytes,
            "ids_bytes_compact": float(np.ceil(np.log2(max(2, g.n)))) * edges / 8.0
            + map_bytes,
            "payload_bytes": float(g.x.nbytes),
            "payload_bytes_unc": float(g.x.nbytes),
            "centroid_bytes": 0.0,
            "decoded_cache_bytes": cache["bytes"],
            "total_bytes": id_bytes + map_bytes + g.x.nbytes + cache["bytes"],
        }


def as_api_index(index):
    """Upgrade a raw IVFIndex/GraphIndex to the protocol (identity otherwise)."""
    if isinstance(index, (FlatIndex, IVFApiIndex, GraphApiIndex)):
        return index
    if isinstance(index, IVFIndex):
        return IVFApiIndex.from_built(index)
    if isinstance(index, GraphIndex):
        return GraphApiIndex.from_built(index)
    if isinstance(index, Index):
        return index  # already protocol-shaped

    raise TypeError(f"cannot adapt {type(index).__name__} to repro.api.Index")


def make_index(spec) -> "FlatIndex | IVFApiIndex | GraphApiIndex":
    """Spec (string or IndexSpec) -> empty index of the right class."""
    spec = parse_spec(spec)
    if spec.kind == "flat":
        return FlatIndex(spec)
    if spec.kind == "ivf":
        return IVFApiIndex(spec)
    return GraphApiIndex(spec)
