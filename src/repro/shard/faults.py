"""Fault layer for the sharded router: deadlines, retries, injection.

A sharded service multiplies the ways one query batch can fail — any of
N shard workers can be slow, wedged or gone.  The router's contract is
**graceful degradation**: a failing shard costs recall (its partition's
candidates go missing) but never costs availability.  This module holds
the pieces the router composes:

* :class:`RetryPolicy` — attempts + exponential backoff between them
  (``sleep`` is injectable so tests never really wait).
* :class:`FaultPolicy` — the injection hook.  The router calls
  ``on_attempt(shard_id, attempt, batch_id)`` right before each per-shard
  search attempt; the hook may sleep (simulating a slow shard, tripping
  the router's deadline) or raise (simulating a dead or flaky one).  The
  default policy does nothing; tests use :class:`ScriptedFaults` to kill
  or delay specific shards deterministically, and the serving example
  uses :class:`RandomFaults` for a seeded background failure rate.
* Exceptions — :class:`ShardTimeout` (retryable), :class:`ShardDead`
  (not retryable: a dead process won't heal between backoffs).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import numpy as np

__all__ = ["ShardFault", "ShardTimeout", "ShardDead", "RetryPolicy",
           "FaultPolicy", "ScriptedFaults", "RandomFaults"]


class ShardFault(Exception):
    """Base class for injected/observed per-shard failures."""


class ShardTimeout(ShardFault):
    """A shard attempt exceeded its deadline; retrying may succeed."""


class ShardDead(ShardFault):
    """A shard is gone; retrying is pointless (fail fast, degrade)."""


@dataclasses.dataclass
class RetryPolicy:
    """Per-shard retry-with-backoff knobs.

    ``max_attempts`` counts the first try (1 = no retries).  Attempt
    ``i`` (0-based) that fails retryably sleeps
    ``backoff_s * backoff_mult**i`` before attempt ``i+1``.
    """

    max_attempts: int = 2
    backoff_s: float = 0.005
    backoff_mult: float = 2.0
    sleep: Callable[[float], None] = time.sleep

    def backoff(self, attempt: int) -> float:
        return self.backoff_s * self.backoff_mult ** attempt


class FaultPolicy:
    """No-op injection hook; subclass to script failures.

    ``on_attempt`` runs in the shard's worker thread immediately before
    the search attempt.  Raise to fail the attempt (:class:`ShardDead`
    skips retries); sleep to simulate slowness against the router's
    ``deadline_s``.
    """

    def on_attempt(self, shard_id: int, attempt: int, batch_id: int) -> None:
        del shard_id, attempt, batch_id

    def reset(self) -> None:
        """Forget scripted state (e.g. between test phases)."""


class ScriptedFaults(FaultPolicy):
    """Deterministic per-shard faults for tests.

    * ``dead`` — shard ids that always raise :class:`ShardDead`.
    * ``flaky`` — shard id -> number of attempts that raise
      :class:`ShardTimeout` before succeeding (exercises the retry path).
    * ``delay_s`` — shard id -> real sleep before each attempt (trips the
      router's wall-clock deadline).
    """

    def __init__(self, dead=(), flaky: Optional[Dict[int, int]] = None,
                 delay_s: Optional[Dict[int, float]] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.dead = frozenset(dead)
        self.flaky = dict(flaky or {})
        self.delay_s = dict(delay_s or {})
        self._sleep = sleep
        self.injected = 0

    def on_attempt(self, shard_id: int, attempt: int, batch_id: int) -> None:
        if shard_id in self.dead:
            self.injected += 1
            raise ShardDead(f"shard {shard_id} scripted dead")
        if shard_id in self.delay_s:
            self._sleep(self.delay_s[shard_id])
        if self.flaky.get(shard_id, 0) > attempt:
            self.injected += 1
            raise ShardTimeout(
                f"shard {shard_id} scripted timeout (attempt {attempt})")


class RandomFaults(FaultPolicy):
    """Seeded Bernoulli(``rate``) retryable failure per attempt.

    Deterministic given ``seed``, so the ``--fault-rate`` demo in
    ``examples/serve_ann.py`` reproduces run to run.
    """

    def __init__(self, rate: float, seed: int = 0):
        if not 0.0 <= rate <= 1.0:
            raise ValueError("fault rate must be in [0, 1]")
        self.rate = float(rate)
        self._rng = np.random.default_rng(seed)
        self.injected = 0

    def on_attempt(self, shard_id: int, attempt: int, batch_id: int) -> None:
        del batch_id
        if self._rng.random() < self.rate:
            self.injected += 1
            raise ShardTimeout(
                f"shard {shard_id} random fault (attempt {attempt})")
