"""repro.shard — sharded ANN serving: planner, scatter/merge router, faults.

One machine's RAM bounds one :class:`repro.serve.AnnService`; this
package is the capacity story past that bound.  ``plan_shards`` splits a
built index into N standalone shard artifacts (RIDX containers + a JSON
manifest),
:class:`ShardedAnnService` scatters query batches across per-shard
workers and k-way merges the answers bit-identically to the unsharded
index, and :mod:`repro.shard.faults` degrades gracefully when shards
slow down or die.
"""

from .faults import (FaultPolicy, RandomFaults, RetryPolicy, ScriptedFaults,
                     ShardDead, ShardFault, ShardTimeout)
from .plan import ShardInfo, ShardPlan, plan_shards
from .service import ShardedAnnService, ShardTicket

__all__ = [
    "plan_shards", "ShardPlan", "ShardInfo",
    "ShardedAnnService", "ShardTicket",
    "FaultPolicy", "ScriptedFaults", "RandomFaults", "RetryPolicy",
    "ShardFault", "ShardTimeout", "ShardDead",
]
