"""Shard planner: split one built index into N servable shard artifacts.

The paper's compression argument is a per-machine capacity argument —
compressed ids mean more of the database fits in one process.  Past one
machine the database must be partitioned, and the ``repro.api`` seam
makes the shard unit trivial: each shard is itself a factory-spec index,
serialized as a standalone RIDX blob, described by one JSON manifest.

Partitioning schemes (all deterministic):

* **IVF — cluster granularity** (``by="range"`` contiguous cluster
  ranges, ``by="hash"`` splitmix-hashed cluster ids).  Every shard keeps
  the **full coarse quantizer** (all ``nlist`` centroids) but owns only
  its clusters' lists/vectors; unowned clusters are empty.  Because both
  scan engines skip empty clusters, each shard probes the *globally*
  nearest ``nprobe`` centroids and scores exactly the owned subset of the
  monolithic candidate set — so the router's ``(dist, key)`` merge is
  bit-identical to the unsharded search (repro.shard.service).  Shards
  keep the global id universe ``n``: their streams decode straight to
  database ids, no remap.
* **Flat / NSG / HNSW — vector-id hash** (``by="hash"``).  Each shard
  holds a row subset in ascending global-id order plus an explicit
  ``id_map`` (serialized in the RIDX blob).  Graph shards rebuild their
  spec's graph over the subset; sharded graph search equals monolithic
  search whenever both are exhaustive (``ef >= n``) and otherwise trades
  recall for capacity like any partitioned HNSW deployment.

``assignments=`` overrides the scheme with an explicit owner array
(clusters for IVF, ids otherwise) — how tests build pathologically
uneven shards.
"""

from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import List, Optional, Sequence

import numpy as np

from ..ann.graph import GraphIndex, build_hnsw, build_nsg
from ..ann.ivf import IVFIndex
from ..ann.scan import _spans_concat
from ..core.polya import PolyaCodec
from ..api.container import load_index, save_index
from ..api.indexes import (FlatIndex, GraphApiIndex, IVFApiIndex,
                           as_api_index)
from ..api.spec import parse_spec

__all__ = ["ShardInfo", "ShardPlan", "plan_shards", "MANIFEST_NAME"]

MANIFEST_NAME = "shards.json"
MANIFEST_FORMAT = "ridx-shards"
MANIFEST_VERSION = 1


def _hash_owner(keys: np.ndarray, nshards: int) -> np.ndarray:
    """splitmix64 finalizer -> shard owner per key (deterministic)."""
    x = np.asarray(keys, np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return (x % np.uint64(nshards)).astype(np.int64)


@dataclasses.dataclass
class ShardInfo:
    """One row of the shard manifest."""

    shard_id: int
    spec: str                        # canonical factory spec of the shard
    n_local: int                     # vectors held by this shard
    clusters: Optional[list] = None  # IVF: [lo, hi) range or explicit list
    id_range: Optional[list] = None  # [min, max] global ids held
    ledger: dict = dataclasses.field(default_factory=dict)
    path: Optional[str] = None       # RIDX artifact, relative to the manifest

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "ShardInfo":
        return cls(**d)


@dataclasses.dataclass
class ShardPlan:
    """A partitioning of one index: manifest rows + the live shard indexes."""

    kind: str                        # "ivf" | "flat" | "nsg" | "hnsw"
    by: str                          # "range" | "hash" | "custom"
    nshards: int
    source_spec: str
    n: int                           # global id universe
    shards: List[ShardInfo]
    indexes: List[object]            # repro.api indexes, parallel to shards

    def manifest(self) -> dict:
        return {
            "format": MANIFEST_FORMAT,
            "version": MANIFEST_VERSION,
            "kind": self.kind,
            "by": self.by,
            "nshards": self.nshards,
            "source_spec": self.source_spec,
            "n": self.n,
            "shards": [s.to_json() for s in self.shards],
        }

    def cluster_owner(self) -> np.ndarray:
        """IVF plans: owner shard id per cluster (``(nlist,)`` int64).

        The routing table for online ingest — a new vector goes to the
        shard owning its nearest centroid's cluster."""
        if self.kind != "ivf":
            raise ValueError("cluster_owner() applies to IVF plans only")
        nlist = parse_spec(self.source_spec).nlist
        owner = np.full(nlist, -1, np.int64)
        for info in self.shards:
            c = info.clusters
            if c is None:
                continue
            if self.by == "range":
                owner[int(c[0]):int(c[1])] = info.shard_id
            else:
                owner[np.asarray(c, np.int64)] = info.shard_id
        return owner

    def id_owner(self, ids: np.ndarray) -> np.ndarray:
        """Flat/graph hash plans: owner shard per (new) global id."""
        if self.kind == "ivf":
            raise ValueError("IVF ingest routes by cluster_owner()")
        if self.by != "hash":
            raise ValueError(
                f"by={self.by!r} plans have no rule for unseen ids")
        return _hash_owner(np.asarray(ids, np.int64), self.nshards)

    def save(self, out_dir) -> Path:
        """Write per-shard RIDX artifacts + ``shards.json``; returns
        the manifest path."""
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        for info, idx in zip(self.shards, self.indexes):
            info.path = f"shard_{info.shard_id:03d}.ridx"
            save_index(idx, out / info.path)
        mpath = out / MANIFEST_NAME
        mpath.write_text(json.dumps(self.manifest(), indent=1))
        return mpath

    @classmethod
    def load(cls, src) -> "ShardPlan":
        """Load a saved plan from a manifest path or its directory."""
        p = Path(src)
        if p.is_dir():
            p = p / MANIFEST_NAME
        m = json.loads(p.read_text())
        if m.get("format") != MANIFEST_FORMAT:
            raise ValueError(f"{p} is not a {MANIFEST_FORMAT} manifest")
        if m.get("version") != MANIFEST_VERSION:
            raise ValueError(f"unsupported shard-manifest version "
                             f"{m.get('version')}")
        shards = [ShardInfo.from_json(d) for d in m["shards"]]
        indexes = [load_index(p.parent / s.path) for s in shards]
        return cls(kind=m["kind"], by=m["by"], nshards=m["nshards"],
                   source_spec=m["source_spec"], n=m["n"],
                   shards=shards, indexes=indexes)


# ---------------------------------------------------------------------------
# splitters
# ---------------------------------------------------------------------------

def _cache_bytes(spec) -> Optional[int]:
    return (int(spec.cache_mb * (1 << 20))
            if spec.cache_mb is not None else None)


def _split_ivf(src: IVFIndex, owner: np.ndarray,
               nshards: int) -> List[IVFIndex]:
    """Cluster-granular split; every shard keeps the full quantizer, the
    global id universe AND the global epoch boundaries (see module doc
    and repro.core.epoch for why that buys bit-parity)."""
    out = []
    starts = src.offsets[:-1]
    for s in range(nshards):
        mask = owner == s
        sh = IVFIndex(nlist=src.nlist, id_codec=src.id_codec, pq=src.pq,
                      code_codec=src.code_codec, cache_bytes=src.cache_bytes,
                      cache_policy=src.cache_policy,
                      max_epochs=src.max_epochs)
        sh.n, sh.d = src.n, src.d
        sh.centroids = src.centroids          # shared coarse quantizer
        sh.cluster_of = src.cluster_of
        sh.sizes = np.where(mask, src.sizes, 0)
        sh.offsets = np.concatenate([[0], np.cumsum(sh.sizes)]).astype(np.int64)
        sh._lists = [src._lists[k] if mask[k] else np.zeros(0, np.int64)
                     for k in range(src.nlist)]
        rows = _spans_concat(starts[mask].astype(np.int64),
                             src.sizes[mask].astype(np.int64))
        if src.codes is not None:
            sh.codes, sh.vecs = src.codes[rows], None
        else:
            sh.codes, sh.vecs = None, src.vecs[rows]
        # owned epoch blobs are the monolithic ones verbatim (same relative
        # list, same universe -> same bytes); unowned clusters empty
        sh._ids = src._ids.split(mask, src._lists)
        if getattr(src, "_code_blobs", None) is not None:
            # per-epoch polya over the owned rows (cluster rows are stored
            # epoch-ascending, so each epoch is a contiguous sub-span)
            cum = sh._ids._cum
            sh._polya = PolyaCodec()
            sh._code_blobs = [
                sh._polya.encode(
                    [sh.codes[sh.offsets[k] + cum[e, k]:
                              sh.offsets[k] + cum[e + 1, k]]
                     for k in range(sh.nlist)])
                for e in range(sh._ids.n_epochs)]
        else:
            sh._code_blobs = None
        sh._decoded_cache = sh._new_cache()
        out.append(sh)
    return out


def _split_flat(src: FlatIndex, owner: np.ndarray,
                nshards: int) -> List[FlatIndex]:
    src_map = getattr(src, "id_map", None)
    out = []
    for s in range(nshards):
        ids = np.flatnonzero(owner == s).astype(np.int64)  # ascending
        sh = FlatIndex(src.index_spec).build(src.vecs[ids])
        sh.id_map = ids if src_map is None else src_map[ids]
        out.append(sh)
    return out


def _split_graph(src: GraphApiIndex, owner: np.ndarray, nshards: int,
                 seed: int) -> List[GraphApiIndex]:
    spec = src.index_spec
    g = src.graph
    builder = build_nsg if spec.kind == "nsg" else build_hnsw
    out = []
    for s in range(nshards):
        ids = np.flatnonzero(owner == s).astype(np.int64)
        if ids.size == 0:
            raise ValueError(
                f"graph shard {s} would be empty ({nshards} shards over "
                f"{g.n} vectors); use fewer shards or pass assignments=")
        if ids.size == g.n:
            sub = g                            # whole index: serve as-is
        else:
            xs = g.x[ids]
            if ids.size < 2:
                adj = [np.zeros(0, np.int64) for _ in range(ids.size)]
            else:
                adj = builder(xs, spec.degree, seed=seed)
            sub = GraphIndex(id_codec=spec.ids,
                             cache_bytes=_cache_bytes(spec),
                             cache_policy=spec.cache_policy or "lru",
                             max_epochs=spec.max_epochs).build(xs, adj)
            sub.id_map = ids
        out.append(GraphApiIndex.from_built(sub, spec))
    return out


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

def plan_shards(index, nshards: int, by: Optional[str] = None,
                boundaries: Optional[Sequence[int]] = None,
                assignments: Optional[np.ndarray] = None,
                seed: int = 0) -> ShardPlan:
    """Split a built index into ``nshards`` servable shards.

    ``by``: ``"range"`` (IVF default — contiguous cluster ranges, optionally
    at explicit ``boundaries``, a sorted ``nshards+1`` edge list) or
    ``"hash"`` (IVF clusters / Flat-graph vector ids, splitmix64).
    ``assignments`` overrides both: an owner array over clusters (IVF) or
    ids (Flat/graph) with values in ``[0, nshards)``.

    Returns a :class:`ShardPlan` holding live api indexes plus the
    manifest rows; ``plan.save(dir)`` persists RIDX artifacts + JSON.
    """
    if nshards <= 0:
        raise ValueError("nshards must be positive")
    index = as_api_index(index)
    spec = parse_spec(index.spec)
    kind = spec.kind

    if kind == "ivf":
        ivf = index.ivf
        nunits, unit = ivf.nlist, "cluster"
    else:
        nunits, unit = index.n, "id"

    if assignments is not None:
        owner = np.asarray(assignments, np.int64)
        if owner.shape != (nunits,):
            raise ValueError(f"assignments must map each {unit} "
                             f"(shape ({nunits},), got {owner.shape})")
        if owner.size and (owner.min() < 0 or owner.max() >= nshards):
            raise ValueError("assignments out of range for nshards")
        by = "custom"
    elif kind == "ivf":
        by = by or "range"
        if by == "range":
            edges = (np.asarray(boundaries, np.int64) if boundaries is not None
                     else np.linspace(0, nunits, nshards + 1).astype(np.int64))
            if (edges.shape != (nshards + 1,) or edges[0] != 0
                    or edges[-1] != nunits or np.any(np.diff(edges) < 0)):
                raise ValueError(
                    f"boundaries must be a sorted edge list 0..{nunits} "
                    f"of length {nshards + 1}")
            owner = np.repeat(np.arange(nshards, dtype=np.int64),
                              np.diff(edges))
        elif by == "hash":
            if boundaries is not None:
                raise ValueError("boundaries only apply to by='range'")
            owner = _hash_owner(np.arange(nunits), nshards)
        else:
            raise ValueError(f"unknown IVF partition scheme {by!r} "
                             "(options: range, hash)")
    else:
        by = by or "hash"
        if by != "hash":
            raise ValueError(f"{kind} indexes shard by vector-id hash only "
                             f"(got by={by!r})")
        owner = _hash_owner(np.arange(nunits), nshards)

    # -- build per-shard indexes -------------------------------------------
    if kind == "ivf":
        parts = _split_ivf(index.ivf, owner, nshards)
        shard_indexes = [IVFApiIndex.from_built(p, spec) for p in parts]
    elif kind == "flat":
        shard_indexes = _split_flat(index, owner, nshards)
    else:
        shard_indexes = _split_graph(index, owner, nshards, seed)

    # -- manifest rows ------------------------------------------------------
    infos = []
    for s, sh in enumerate(shard_indexes):
        if kind == "ivf":
            held = np.flatnonzero(owner == s)
            lists = [sh.ivf._lists[int(k)] for k in held
                     if len(sh.ivf._lists[int(k)])]
            all_ids = np.concatenate(lists) if lists else np.zeros(0, np.int64)
            n_local = int(sh.ivf.sizes.sum())
            if by == "range":
                lo = int(held[0]) if held.size else 0
                hi = int(held[-1]) + 1 if held.size else 0
                clusters = [lo, hi]
            else:
                clusters = [int(k) for k in held]
        else:
            all_ids = (getattr(sh, "id_map", None)
                       if kind == "flat"
                       else getattr(sh.graph, "id_map", None))
            if all_ids is None:          # whole-index graph shard
                all_ids = np.arange(index.n, dtype=np.int64)
            n_local = int(all_ids.size)
            clusters = None
        infos.append(ShardInfo(
            shard_id=s,
            spec=str(spec),
            n_local=n_local,
            clusters=clusters,
            id_range=([int(all_ids.min()), int(all_ids.max())]
                      if all_ids.size else None),
            ledger={k: float(v) for k, v in sh.memory_ledger().items()},
        ))

    return ShardPlan(kind=kind, by=by, nshards=nshards,
                     source_spec=str(spec), n=int(index.n),
                     shards=infos, indexes=shard_indexes)
