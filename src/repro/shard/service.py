"""ShardedAnnService — scatter/merge router over per-shard AnnServices.

Same request surface as :class:`repro.serve.AnnService`
(``submit``/``tick``/``flush``/``search``/``stats``/``memory_ledger``),
but the flushed query block fans out to N shard workers on a thread pool
and the per-shard top-k lists are k-way merged back into one answer.

**Bit-parity.**  With no faults the merged ``(dists, ids)`` are
bit-identical to searching the unsharded index, for every id codec and
scan engine.  Distances match because every shard scores its candidates
with the same kernels over the same stored vectors/codes; the subtle part
is *order under distance ties*.  The monolithic engines break ties by
candidate position (IVF: probe rank then in-cluster offset; Flat/graph:
vector id), so each IVF shard search runs ``with_keys=True`` and returns
a ``(probe_rank << 40) | offset`` merge key per result — globally
comparable because all shards share the coarse quantizer, hence see the
same probe ranking (repro.shard.plan).  The router merges per query by
``(dist, key)`` via a stable lexsort, reproducing the monolithic order
exactly.  Flat/graph shards merge by ``(dist, global id)``, their
monolithic tie convention.

**Degraded mode.**  Each shard attempt runs under the
:mod:`repro.shard.faults` retry policy and a router-wide wall-clock
deadline.  A shard that exhausts retries, dies or misses the deadline is
dropped from the merge: the batch completes from the surviving shards'
results with ``stats.partial=True`` and ``stats.shards_failed`` set —
never an exception *for shard faults*.  Only the shard fault taxonomy is
degradable (``ShardTimeout``/``ShardDead``/timeouts); programming errors
inside a worker propagate so bugs can't hide as "partial" batches
(RPA006 in ``repro.analysis``).  ``FaultPolicy`` is the injection seam
tests use to script kills and delays.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from pathlib import Path
from threading import Lock
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..ann.scan import MERGE_KEY_PAD
from ..ann.stats import SearchStats, combine_stats
from ..api.protocol import IvfBacked
from ..serve.ann_service import AddTicket, AnnService, BatchPolicy
from .faults import FaultPolicy, RetryPolicy, ShardDead, ShardTimeout
from .plan import ShardPlan

__all__ = ["ShardedAnnService", "ShardTicket"]


@dataclasses.dataclass
class ShardTicket:
    """One request's handle; filled in when its batch is flushed."""

    request_id: int
    n_queries: int
    enqueued_at: float
    done: bool = False
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None
    stats: Optional[SearchStats] = None  # merged batch stats (shared)
    batch_id: int = -1
    batch_size: int = 0
    wait_s: float = 0.0
    latency_s: float = 0.0


@dataclasses.dataclass
class _ShardResult:
    ids: np.ndarray
    dists: np.ndarray
    keys: Optional[np.ndarray]
    stats: Optional[SearchStats]
    attempts: int                     # 1 = first try succeeded


class ShardedAnnService:
    """Scatter/merge front-end over shard indexes.

    ``shards`` may be a :class:`repro.shard.ShardPlan`, a saved-plan
    directory/manifest path, or a plain sequence of indexes.  Each shard
    gets its own single-threaded :class:`AnnService` worker (guarded by a
    lock — a timed-out attempt may still be running when the router moves
    on); a ``cache_mb`` budget is split evenly across workers.

    ``deadline_s`` bounds each flush's scatter wall-clock; ``retry``
    and ``fault_policy`` come from :mod:`repro.shard.faults`.
    """

    def __init__(self, shards, topk: int = 10,
                 policy: Optional[BatchPolicy] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 cache_mb: Optional[float] = None,
                 deadline_s: Optional[float] = None,
                 retry: Optional[RetryPolicy] = None,
                 fault_policy: Optional[FaultPolicy] = None,
                 **search_opts):
        from ..api.indexes import as_api_index

        self.plan: Optional[ShardPlan] = None
        if isinstance(shards, ShardPlan):
            self.plan = shards
            indexes = list(shards.indexes)
        elif isinstance(shards, (str, Path)):
            self.plan = ShardPlan.load(shards)
            indexes = list(self.plan.indexes)
        else:
            indexes = [as_api_index(s) for s in shards]
        if not indexes:
            raise ValueError("need at least one shard")
        self.nshards = len(indexes)
        self.topk = topk
        self.policy = policy or BatchPolicy()
        self.clock = clock
        self.deadline_s = deadline_s
        self.retry = retry or RetryPolicy()
        self.fault_policy = fault_policy
        per_cache = (cache_mb / self.nshards) if cache_mb is not None else None
        # workers never micro-batch on their own: the router owns batching
        worker_policy = BatchPolicy(max_batch=1 << 30, max_wait_s=float("inf"))
        self._workers: List[AnnService] = []
        for idx in indexes:
            opts = dict(search_opts)
            if isinstance(idx, IvfBacked):
                opts["with_keys"] = True   # IVF tie keys for the stable merge
            self._workers.append(AnnService(
                idx, topk=topk, policy=worker_policy, clock=clock,
                cache_mb=per_cache, **opts))
        self._locks = [Lock() for _ in range(self.nshards)]
        self._pool = ThreadPoolExecutor(
            max_workers=max(4, 2 * self.nshards),
            thread_name_prefix="shard")
        self._pending: List[ShardTicket] = []
        self._pending_q: List[np.ndarray] = []
        self._pending_add: List[AddTicket] = []
        self._pending_add_x: List[np.ndarray] = []
        self._n = int(self.plan.n) if self.plan is not None else 0
        self._cluster_owner: Optional[np.ndarray] = None
        self._next_id = 0
        self.reset_stats()

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Shut down the scatter thread pool (also via context manager)."""
        self._pool.shutdown(wait=False)

    def __enter__(self) -> "ShardedAnnService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def reset_stats(self) -> None:
        """Zero the router counters (e.g. after a jit warm-up call)."""
        self.requests = 0
        self.queries = 0
        self.batches = 0
        self.adds = 0
        self.add_rows = 0
        self.add_batches = 0
        self.add_s = 0.0
        self.partial_batches = 0
        self.shards_failed = 0
        self.retries = 0
        self.search_s = 0.0
        self.merge_s = 0.0
        self.fault_log: "deque[tuple]" = deque(maxlen=256)
        self._batch_sizes: "deque[int]" = deque(maxlen=4096)
        self._waits: "deque[float]" = deque(maxlen=4096)
        self._lats: "deque[float]" = deque(maxlen=4096)
        for w in self._workers:
            w.reset_stats()

    # -- request path --------------------------------------------------------
    def submit(self, queries: np.ndarray) -> ShardTicket:
        """Enqueue one request (``(nq, d)`` or ``(d,)``); may trigger a flush."""
        queries = np.asarray(queries)
        if queries.ndim == 1:
            queries = queries[None]
        t = ShardTicket(request_id=self._next_id,
                        n_queries=queries.shape[0],
                        enqueued_at=self.clock())
        self._next_id += 1
        self._pending.append(t)
        self._pending_q.append(queries)
        self.requests += 1
        self.queries += queries.shape[0]
        if self.pending() >= self.policy.max_batch:
            self.flush()
        else:
            self.tick()
        return t

    # -- ingest path ---------------------------------------------------------
    def submit_add(self, x: np.ndarray) -> AddTicket:
        """Enqueue rows for routed ingest (``(m, d)`` or ``(d,)``).

        Needs a :class:`ShardPlan` (the routing table).  Rows batch under
        the same micro-batching policy as queries and are applied by
        :meth:`flush_adds`: IVF plans assign each row to its nearest
        centroid's cluster and hand it to the shard owning that cluster —
        every shard seals the epoch with the *global* row count, so epoch
        boundaries (hence blob bytes) match the monolithic index.  Flat /
        graph hash plans route by the id-hash rule.  Query flushes apply
        pending adds first (read-your-writes).
        """
        if self.plan is None:
            raise ValueError("routed ingest needs a ShardPlan "
                             "(construct the service from a plan)")
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None]
        t = AddTicket(request_id=self._next_id, n_rows=x.shape[0],
                      enqueued_at=self.clock())
        self._next_id += 1
        self._pending_add.append(t)
        self._pending_add_x.append(x)
        self.adds += 1
        self.add_rows += x.shape[0]
        if self.pending_adds() >= self.policy.max_batch:
            self.flush_adds()
        else:
            self.tick()
        return t

    def flush_adds(self) -> List[AddTicket]:
        """Route every pending add to its owning shard as one epoch."""
        if not self._pending_add:
            return []
        tickets, self._pending_add = self._pending_add, []
        xs, self._pending_add_x = self._pending_add_x, []
        now = self.clock()
        x = np.concatenate(xs, axis=0)
        m = x.shape[0]
        base = self._n
        ids = np.arange(base, base + m, dtype=np.int64)
        t0 = time.perf_counter()
        if m:
            if self.plan.kind == "ivf":
                from ..ann.kmeans import assign

                if self._cluster_owner is None:
                    self._cluster_owner = self.plan.cluster_owner()
                clusters = assign(
                    x, self._workers[0].index.ivf.centroids)
                owner = self._cluster_owner[clusters]
                if np.any(owner < 0):
                    raise ValueError("plan does not own every cluster")
                # EVERY shard seals the epoch (global count), rows or not
                for s in range(self.nshards):
                    sel = owner == s
                    with self._locks[s]:
                        self._workers[s].index.append_rows(
                            x[sel], ids[sel], count=m)
            else:
                owner = self.plan.id_owner(ids)
                for s in range(self.nshards):
                    sel = owner == s
                    if not sel.any():
                        continue
                    with self._locks[s]:
                        self._workers[s].index.append_rows(x[sel], ids[sel])
            self._n = base + m
            self.plan.n = self._n
        apply_s = time.perf_counter() - t0
        self.add_batches += 1
        self.add_s += apply_s
        row = 0
        for t in tickets:
            t.ids = ids[row: row + t.n_rows]
            row += t.n_rows
            t.done = True
            t.batch_id = self.add_batches - 1
            t.batch_size = m
            t.wait_s = max(0.0, now - t.enqueued_at)
            t.apply_s = apply_s
        return tickets

    def add(self, x: np.ndarray) -> AddTicket:
        """Synchronous ingest convenience: submit + immediate apply."""
        t = self.submit_add(x)
        if not t.done:
            self.flush_adds()
        return t

    def pending_adds(self) -> int:
        """Rows currently queued for ingest (not yet routed to shards)."""
        return sum(t.n_rows for t in self._pending_add)

    def tick(self) -> bool:
        """Flush if the oldest pending request exceeded the wait budget."""
        fired = False
        if self._pending_add and (self.clock() - self._pending_add[0].enqueued_at
                                  >= self.policy.max_wait_s):
            self.flush_adds()
            fired = True
        if not self._pending:
            return fired
        if self.clock() - self._pending[0].enqueued_at >= self.policy.max_wait_s:
            self.flush()
            return True
        return fired

    def flush(self) -> List[ShardTicket]:
        """Scatter everything pending to all shards, merge, fill tickets."""
        # read-your-writes: rows submitted before these queries must be live
        self.flush_adds()
        if not self._pending:
            return []
        tickets, self._pending = self._pending, []
        qs, self._pending_q = self._pending_q, []
        now = self.clock()
        batch = np.concatenate(qs, axis=0)
        batch_id = self.batches

        t0 = time.perf_counter()
        results = self._scatter(batch, batch_id)
        scatter_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        dists, ids = self._merge(batch.shape[0],
                                 [r for r in results if r is not None])
        merge_s = time.perf_counter() - t0

        live = [r for r in results if r is not None]
        n_failed = self.nshards - len(live)
        st = combine_stats([r.stats for r in live if r.stats is not None],
                           wall_s=scatter_s + merge_s, merge_s=merge_s)
        st.shards = self.nshards
        st.shards_failed = n_failed
        st.partial = n_failed > 0
        st.retries = sum(r.attempts - 1 for r in live)

        done_at = self.clock()
        self.batches += 1
        self.partial_batches += int(st.partial)
        self.shards_failed += n_failed
        self.retries += st.retries
        self.search_s += scatter_s + merge_s
        self.merge_s += merge_s
        self._batch_sizes.append(batch.shape[0])
        row = 0
        for t in tickets:
            t.ids = ids[row: row + t.n_queries]
            t.dists = dists[row: row + t.n_queries]
            row += t.n_queries
            t.stats = st
            t.done = True
            t.batch_id = batch_id
            t.batch_size = batch.shape[0]
            t.wait_s = max(0.0, now - t.enqueued_at)
            t.latency_s = max(0.0, done_at - t.enqueued_at)
            self._waits.append(t.wait_s)
            self._lats.append(t.latency_s)
        return tickets

    def search(self, queries: np.ndarray,
               with_stats: bool = False):
        """Synchronous convenience: submit + immediate flush.

        Returns ``(ids, dists)`` like ``AnnService.search``; pass
        ``with_stats=True`` for ``(ids, dists, stats)`` with the merged
        :class:`SearchStats` (``partial``/``shards_failed``/``retries``).
        """
        t = self.submit(queries)
        if not t.done:
            self.flush()
        return (t.ids, t.dists, t.stats) if with_stats else (t.ids, t.dists)

    def pending(self) -> int:
        """Queries currently queued for search (not yet scattered)."""
        return sum(t.n_queries for t in self._pending)

    # -- scatter -------------------------------------------------------------
    def _scatter(self, batch: np.ndarray,
                 batch_id: int) -> List[Optional[_ShardResult]]:
        futs = [self._pool.submit(self._attempt_shard, s, batch, batch_id)
                for s in range(self.nshards)]
        end = (time.monotonic() + self.deadline_s
               if self.deadline_s is not None else None)
        out: List[Optional[_ShardResult]] = [None] * self.nshards
        for s, f in enumerate(futs):
            try:
                timeout = (max(0.0, end - time.monotonic())
                           if end is not None else None)
                out[s] = f.result(timeout=timeout)
            except (ShardTimeout, ShardDead, TimeoutError,
                    FuturesTimeout) as e:
                # degrade: drop the shard from the merge (stats.partial);
                # programming errors propagate instead of being swallowed
                self.fault_log.append((batch_id, s, repr(e)))
        return out

    def _attempt_shard(self, s: int, batch: np.ndarray,
                       batch_id: int) -> _ShardResult:
        """One shard's retry loop; runs on the pool.  The per-shard lock
        serializes attempts with any orphaned (timed-out) predecessor."""
        attempt = 0
        with self._locks[s]:
            while True:
                try:
                    if self.fault_policy is not None:
                        self.fault_policy.on_attempt(s, attempt, batch_id)
                    svc = self._workers[s]
                    t = svc.submit(batch)
                    if not t.done:
                        svc.flush()
                    return _ShardResult(ids=t.ids, dists=t.dists, keys=t.keys,
                                        stats=svc.last_stats,
                                        attempts=attempt + 1)
                except ShardDead:
                    raise                      # dead shards don't heal
                except (ShardTimeout, TimeoutError, FuturesTimeout):
                    attempt += 1
                    if attempt >= self.retry.max_attempts:
                        raise
                    self.retry.sleep(self.retry.backoff(attempt - 1))

    # -- merge ---------------------------------------------------------------
    def _merge(self, nq: int, live: List[_ShardResult]
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Stable per-query k-way merge of shard top-k by ``(dist, key)``."""
        k = self.topk
        if not live:
            return (np.full((nq, k), np.inf, np.float32),
                    np.zeros((nq, k), np.int64))
        dists = np.concatenate([r.dists for r in live], axis=1)
        ids = np.concatenate([r.ids for r in live], axis=1)
        keys = np.concatenate([
            r.keys if r.keys is not None else np.where(
                np.isfinite(r.dists), r.ids.astype(np.uint64), MERGE_KEY_PAD)
            for r in live], axis=1)
        # lexsort: last key is primary -> order by (dist, merge key) per row
        order = np.lexsort((keys, dists), axis=1)[:, :k]
        rq = np.arange(nq)[:, None]
        out_d, out_i = dists[rq, order], ids[rq, order]
        # fewer than k finite candidates: normalize pads to (inf, 0)
        pad = ~np.isfinite(out_d)
        out_i[pad] = 0
        return out_d, out_i

    # -- accounting ----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Router counters + SLO accounting (same latency keys as
        ``AnnService.stats``), plus degradation totals:

        * ``shards`` — shard count.
        * ``partial_batches`` — flushes that completed degraded.
        * ``shards_failed`` / ``retries`` — cumulative failed shard
          attempts dropped from merges, and retry attempts that
          eventually succeeded.
        * ``merge_s`` — cumulative k-way merge wall time (``search_s``
          covers scatter + merge).
        """
        bs = np.asarray(self._batch_sizes, np.float64)
        ws = np.asarray(self._waits, np.float64)
        ls = np.asarray(self._lats, np.float64)
        return {
            "requests": self.requests,
            "queries": self.queries,
            "batches": self.batches,
            "adds": self.adds,
            "add_rows": self.add_rows,
            "add_batches": self.add_batches,
            "add_s": self.add_s,
            "shards": float(self.nshards),
            "partial_batches": float(self.partial_batches),
            "shards_failed": float(self.shards_failed),
            "retries": float(self.retries),
            "mean_batch": float(bs.mean()) if bs.size else 0.0,
            "max_batch": float(bs.max()) if bs.size else 0.0,
            "mean_wait_s": float(ws.mean()) if ws.size else 0.0,
            "p99_wait_s": float(np.quantile(ws, 0.99)) if ws.size else 0.0,
            "mean_latency_s": float(ls.mean()) if ls.size else 0.0,
            "p50_latency_s": float(np.quantile(ls, 0.50)) if ls.size else 0.0,
            "p95_latency_s": float(np.quantile(ls, 0.95)) if ls.size else 0.0,
            "search_s": self.search_s,
            "merge_s": self.merge_s,
            "resolve_s": sum(w.resolve_s for w in self._workers),
            "ndis": sum(w.ndis for w in self._workers),
            "decodes": sum(w.decodes for w in self._workers),
            "host_block_bytes": sum(w.host_block_bytes
                                    for w in self._workers),
            "device_selects": sum(w.device_selects for w in self._workers),
        }

    def worker_stats(self) -> List[Dict[str, float]]:
        """Per-shard ``AnnService.stats()`` dicts, by shard id."""
        return [w.stats() for w in self._workers]

    def memory_ledger(self) -> Dict[str, float]:
        """Aggregate of per-shard ledgers (numeric keys summed), plus the
        shard count.  Per-shard ledgers are in the plan manifest."""
        total: Dict[str, float] = {}
        for w in self._workers:
            for key, v in w.memory_ledger().items():
                total[key] = total.get(key, 0.0) + float(v)
        total["shards"] = float(self.nshards)
        return total
