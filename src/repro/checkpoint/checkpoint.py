"""Fault-tolerant checkpointing: atomic sharded saves, auto-resume, reshard.

Layout (one directory per step)::

    ckpt_dir/
      step_000123/
        manifest.json       # step, flat-key list, data-pipeline state, mesh
        arrays.npz          # flat {key: array} (per-host shard in multi-host)
      LATEST                # atomically-renamed pointer file

Crash safety: writes go to ``step_X.tmp`` and are renamed into place only
after fsync — a killed run can always resume from LATEST (tested by
simulating a mid-write crash in tests/test_checkpoint.py).  Elastic
re-scale: arrays are stored unsharded-logical (gathered), so restoring onto
a different mesh just re-applies the new sharding rules (reshard()).
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "reshard"]


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(template):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str | Path, step: int, state: Any,
                    extra: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # fsync then atomic rename — the crash-safety boundary
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest = ckpt_dir / "LATEST"
    tmp_latest = ckpt_dir / "LATEST.tmp"
    tmp_latest.write_text(str(step))
    os.replace(tmp_latest, latest)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    latest = ckpt_dir / "LATEST"
    if not latest.exists():
        return None
    step = int(latest.read_text().strip())
    if not (ckpt_dir / f"step_{step:08d}" / "manifest.json").exists():
        # LATEST points at a half-written dir: fall back to the newest valid
        steps = sorted(
            int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
            if (p / "manifest.json").exists() and not p.name.endswith(".tmp"))
        return steps[-1] if steps else None
    return step


def restore_checkpoint(ckpt_dir: str | Path, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, dict]:
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten_like(template, flat)
    return state, manifest


def reshard(state: Any, shardings: Any) -> Any:
    """Place a host-side state tree onto device shardings (elastic restore)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings)
