"""Config module for --arch qwen2-72b (see registry.py for the entry)."""
from .registry import QWEN2_72B as CONFIG

CONFIG_ID = 'qwen2-72b'
