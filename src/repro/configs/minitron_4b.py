"""Config module for --arch minitron-4b (see registry.py for the entry)."""
from .registry import MINITRON_4B as CONFIG

CONFIG_ID = 'minitron-4b'
