"""Config module for --arch qwen2-vl-7b (see registry.py for the entry)."""
from .registry import QWEN2_VL_7B as CONFIG

CONFIG_ID = 'qwen2-vl-7b'
