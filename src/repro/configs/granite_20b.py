"""Config module for --arch granite-20b (see registry.py for the entry)."""
from .registry import GRANITE_20B as CONFIG

CONFIG_ID = 'granite-20b'
