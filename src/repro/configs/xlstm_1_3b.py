"""Config module for --arch xlstm-1.3b (see registry.py for the entry)."""
from .registry import XLSTM_1P3B as CONFIG

CONFIG_ID = 'xlstm-1.3b'
