"""Model/config schema for the assigned architectures.

Every architecture in the assignment table becomes one frozen ``ModelConfig``
in its own module (``repro/configs/<id>.py``) with the exact dimensions from
the table; ``reduced()`` derives the family-preserving small config used by
the per-arch CPU smoke tests.  Input shapes are separate (``ShapeSpec``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention flavor
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention
    local_global_ratio: int = 0    # gemma3: 5 -> pattern (5 local, 1 global)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # SSM / hybrid (zamba2) / xLSTM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    hybrid_attn_every: int = 0     # zamba2: shared attn block every k layers
    mlstm_slstm_pattern: int = 0   # xlstm: (k mLSTM, 1 sLSTM) super-blocks

    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # modality frontend stub: model consumes precomputed embeddings
    frontend: Optional[str] = None  # None | "audio" | "vision"

    norm_eps: float = 1e-5
    vocab_pad_to: int = 1          # pad vocab to a multiple (sharding)
    dtype: str = "bfloat16"
    # remat policy for the layer scan: "full" recomputes everything in bwd
    # (min memory); "dots" saves matmul outputs (jax dots_saveable) trading
    # HBM for ~25% fewer bwd FLOPs — §Perf hillclimb #3.
    remat_policy: str = "full"

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND rooflines."""
        from repro.models.model import count_params  # lazy; avoids jax import here

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params

        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    pattern = max(
        1,
        cfg.hybrid_attn_every or 0,
        cfg.mlstm_slstm_pattern + 1 if cfg.mlstm_slstm_pattern else 0,
        cfg.local_global_ratio + 1 if cfg.local_global_ratio else 0,
    )
    n_layers = 2 * pattern if pattern > 1 else 2
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        n_encoder_layers=2 if cfg.encoder_decoder else 0,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        vocab_pad_to=1,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else None,
        dtype="float32",
    )
