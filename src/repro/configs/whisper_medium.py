"""Config module for --arch whisper-medium (see registry.py for the entry)."""
from .registry import WHISPER_MEDIUM as CONFIG

CONFIG_ID = 'whisper-medium'
