"""Config module for --arch zamba2-2.7b (see registry.py for the entry)."""
from .registry import ZAMBA2_2P7B as CONFIG

CONFIG_ID = 'zamba2-2.7b'
