"""The 10 assigned architectures, verbatim from the assignment table.

Each is selectable via ``--arch <id>`` in the launchers.  Sources are noted
per entry; dimensions are NOT altered except vocab padding for 16-way
sharding (whisper only; see DESIGN.md §5).
"""

from __future__ import annotations

from typing import Dict

from .base import ModelConfig

__all__ = ["ARCHS", "get_config", "ARCH_IDS"]


ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# — dense ——————————————————————————————————————————————————————————————
# granite-20b [arXiv:2405.04324]: llama-arch code model, MQA (kv=1)
GRANITE_20B = _register(ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
))

# minitron-4b [arXiv:2407.14679]: pruned nemotron, GQA kv=8
MINITRON_4B = _register(ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000, head_dim=128,
))

# qwen2-72b [arXiv:2407.10671]: GQA kv=8, QKV bias
QWEN2_72B = _register(ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128, qkv_bias=True,
    rope_theta=1e6,
))

# gemma3-1b [hf:google/gemma-3-1b-pt]: 5:1 local:global, window 512
GEMMA3_1B = _register(ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    sliding_window=512, local_global_ratio=5, rope_theta=1e6,
))

# — hybrid / ssm ————————————————————————————————————————————————————————
# zamba2-2.7b [arXiv:2411.15242]: Mamba2 backbone + shared attn blocks
ZAMBA2_2P7B = _register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, hybrid_attn_every=6,
))

# xlstm-1.3b [arXiv:2405.04517]: mLSTM + sLSTM blocks, no FFN (d_ff=0)
XLSTM_1P3B = _register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    mlstm_slstm_pattern=5,  # (5 mLSTM, 1 sLSTM) super-blocks x 8
))

# — audio ———————————————————————————————————————————————————————————————
# whisper-medium [arXiv:2212.04356]: enc-dec, conv frontend stubbed.
# vocab 51865 padded to 51968 for 16-way sharding (DESIGN.md §5).
WHISPER_MEDIUM = _register(ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865, head_dim=64,
    encoder_decoder=True, n_encoder_layers=24, frontend="audio",
    vocab_pad_to=256,
))

# — MoE —————————————————————————————————————————————————————————————————
# llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E]: 16e top-1
LLAMA4_SCOUT = _register(ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    n_experts=16, experts_per_token=1, moe_d_ff=8192, shared_expert=True,
    rope_theta=5e5,
))

# olmoe-1b-7b [arXiv:2409.02060]: 64 experts top-8
OLMOE_1B_7B = _register(ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    n_experts=64, experts_per_token=8, moe_d_ff=1024,
))

# — VLM —————————————————————————————————————————————————————————————————
# qwen2-vl-7b [arXiv:2409.12191]: M-RoPE, patch frontend stubbed
QWEN2_VL_7B = _register(ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    mrope_sections=(16, 24, 24), frontend="vision", rope_theta=1e6,
))

ARCH_IDS = tuple(ARCHS)


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; options: {ARCH_IDS}")


# long_500k applicability (DESIGN.md §5): sub-quadratic-capable archs only.
LONG_CONTEXT_ARCHS = ("zamba2-2.7b", "xlstm-1.3b", "gemma3-1b")


def shape_applicable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
