from .base import SHAPES, ModelConfig, ShapeSpec, reduced
from .registry import ARCH_IDS, ARCHS, LONG_CONTEXT_ARCHS, get_config, shape_applicable

__all__ = [
    "SHAPES", "ModelConfig", "ShapeSpec", "reduced",
    "ARCH_IDS", "ARCHS", "LONG_CONTEXT_ARCHS", "get_config", "shape_applicable",
]
