"""Config module for --arch olmoe-1b-7b (see registry.py for the entry)."""
from .registry import OLMOE_1B_7B as CONFIG

CONFIG_ID = 'olmoe-1b-7b'
