"""Config module for --arch gemma3-1b (see registry.py for the entry)."""
from .registry import GEMMA3_1B as CONFIG

CONFIG_ID = 'gemma3-1b'
