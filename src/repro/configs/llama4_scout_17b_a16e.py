"""Config module for --arch llama4-scout-17b-a16e (see registry.py for the entry)."""
from .registry import LLAMA4_SCOUT as CONFIG

CONFIG_ID = 'llama4-scout-17b-a16e'
