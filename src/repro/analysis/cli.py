"""CLI for the static-analysis pass.

``python -m repro.analysis [paths...]`` analyzes ``src/repro`` (or the
given files/directories), subtracts the committed baseline, prints the
remaining findings and exits non-zero if any survive.

Options::

    --baseline PATH       baseline JSON (default: analysis_baseline.json
                          next to the repo root if present)
    --write-baseline      rewrite the baseline from the current findings
                          (grandfathers everything; exits 0)
    --format text|json    output format (default text)
    --rules RPA001,...    run only the named rules
    --show-baselined      also list grandfathered findings (text format)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Sequence

from .core import (Finding, all_checkers, analyze_paths, load_baseline,
                   split_baselined, write_baseline)

DEFAULT_BASELINE = "analysis_baseline.json"


def _default_paths() -> List[str]:
    # prefer src/repro relative to cwd, else the package's own tree
    cand = os.path.join("src", "repro")
    if os.path.isdir(cand):
        return [cand]
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return [here]


def _default_baseline() -> Optional[str]:
    if os.path.exists(DEFAULT_BASELINE):
        return DEFAULT_BASELINE
    return None


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Project-specific static analysis (RPA001-RPA007).")
    p.add_argument("paths", nargs="*", default=None,
                   help="files or directories to analyze "
                        "(default: src/repro)")
    p.add_argument("--baseline", default=None,
                   help="baseline JSON of grandfathered findings "
                        f"(default: {DEFAULT_BASELINE} if present)")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline file from current findings")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   dest="fmt", help="output format")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--show-baselined", action="store_true",
                   help="also print grandfathered findings")
    p.add_argument("--list-rules", action="store_true",
                   help="print the registered rules and exit")
    return p


def _emit_text(new: Sequence[Finding], old: Sequence[Finding],
               show_baselined: bool, out) -> None:
    for f in new:
        print(str(f), file=out)
    if show_baselined:
        for f in old:
            print(f"{f} [baselined]", file=out)
    n_old = f" ({len(old)} baselined)" if old else ""
    print(f"repro.analysis: {len(new)} finding(s){n_old}", file=out)


def _emit_json(new: Sequence[Finding], old: Sequence[Finding], out) -> None:
    payload = {
        "findings": [f.to_dict() for f in new],
        "baselined": [f.to_dict() for f in old],
    }
    json.dump(payload, out, indent=1, sort_keys=True)
    out.write("\n")


def main(argv: Optional[Sequence[str]] = None, out=None) -> int:
    out = out if out is not None else sys.stdout
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for c in all_checkers():
            print(f"{c.rule}  {c.title}", file=out)
        return 0

    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    paths = list(args.paths) if args.paths else _default_paths()
    findings = analyze_paths(paths, rules=rules)

    baseline_path = args.baseline or _default_baseline()
    if args.write_baseline:
        target = args.baseline or DEFAULT_BASELINE
        write_baseline(target, findings)
        print(f"repro.analysis: wrote {len(findings)} finding(s) to "
              f"{target}", file=out)
        return 0

    baseline = load_baseline(baseline_path)
    new, old = split_baselined(findings, baseline)

    if args.fmt == "json":
        _emit_json(new, old, out)
    else:
        _emit_text(new, old, args.show_baselined, out)
    return 1 if new else 0
