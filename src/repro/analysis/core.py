"""Framework for the project's static-analysis pass.

Three small pieces every checker shares:

* :class:`Finding` — one diagnostic: file, line, rule id, message.  The
  *fingerprint* (path + rule + message, no line) is what baselines match
  on, so a grandfathered finding survives unrelated edits above it.
* :class:`Checker` — the visitor contract.  A checker declares its rule
  id, decides per-module whether it ``applies`` (path-scoped rules), and
  returns findings from ``check``.  Concrete checkers register with
  :func:`register` so the CLI and the tier-1 gate run one shared list.
* :class:`ModuleContext` — parsed source handed to checkers: posix-ish
  module path (``repro/...``), source text, AST, and the per-line
  suppression table (``# repro: ignore[RPA001]`` or a bare
  ``# repro: ignore`` for every rule on that line).

Baselines are JSON ({"findings": [{path, rule, message}, ...]}): the
committed file grandfathers known findings; ``--write-baseline``
regenerates it.  The runner (:func:`analyze_paths`) walks ``.py`` files,
skips nothing inside the tree it is pointed at, and returns findings
sorted by (path, line, rule) so output and baselines are deterministic.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

__all__ = [
    "Finding", "Checker", "ModuleContext", "CHECKERS", "register",
    "all_checkers", "analyze_source", "analyze_file", "analyze_paths",
    "iter_python_files", "load_baseline", "write_baseline",
    "split_baselined", "module_path",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic emitted by a checker."""

    path: str
    line: int
    rule: str
    message: str

    @property
    def fingerprint(self) -> str:
        """Baseline identity: stable across line drift."""
        return f"{self.path}::{self.rule}::{self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line,
                "rule": self.rule, "message": self.message}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore(?:\[\s*([A-Za-z0-9_,\s]+?)\s*\])?")


def _suppressions(lines: Sequence[str]) -> Dict[int, Optional[frozenset]]:
    """1-based line -> suppressed rule set (``None`` = every rule)."""
    out: Dict[int, Optional[frozenset]] = {}
    for i, line in enumerate(lines, 1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = m.group(1)
        out[i] = (frozenset(r.strip().upper() for r in rules.split(","))
                  if rules else None)
    return out


def module_path(path: str) -> str:
    """Normalize a filesystem path to the ``repro/...`` form rules scope on.

    Keeps everything from the last ``repro`` path segment onward; paths
    outside a ``repro`` tree pass through posix-normalized (tests hand
    fixture sources a virtual ``repro/...`` path directly).
    """
    p = str(path).replace(os.sep, "/")
    parts = p.split("/")
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i:])
    return p.lstrip("./")


class ModuleContext:
    """Parsed module handed to checkers."""

    def __init__(self, source: str, path: str):
        self.path = module_path(path)
        #: the path as given (filesystem location when analyzing real
        #: files) — rules that consult sibling artifacts (RPA007 reads
        #: docs/architecture.md) walk up from here
        self.fs_path = str(path)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        self.suppressions = _suppressions(self.lines)

    def suppressed(self, line: int, rule: str) -> bool:
        rules = self.suppressions.get(line, False)
        if rules is False:
            return False
        return rules is None or rule in rules


class Checker:
    """One rule: ``applies`` scopes by module, ``check`` emits findings."""

    rule: str = "RPA000"
    title: str = ""

    def applies(self, ctx: ModuleContext) -> bool:
        return True

    def check(self, ctx: ModuleContext) -> List[Finding]:
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node, message: str) -> Finding:
        line = node if isinstance(node, int) else node.lineno
        return Finding(path=ctx.path, line=line, rule=self.rule,
                       message=message)


CHECKERS: List[Type[Checker]] = []


def register(cls: Type[Checker]) -> Type[Checker]:
    CHECKERS.append(cls)
    return cls


def all_checkers(rules: Optional[Iterable[str]] = None) -> List[Checker]:
    # checkers live in a sibling module; import here so `import
    # repro.analysis.core` alone never misses registrations
    from . import checkers as _checkers  # noqa: F401  (registration import)

    wanted = {r.upper() for r in rules} if rules is not None else None
    out = [cls() for cls in CHECKERS]
    if wanted is not None:
        unknown = wanted - {c.rule for c in out}
        if unknown:
            raise ValueError(f"unknown rule(s): {sorted(unknown)}")
        out = [c for c in out if c.rule in wanted]
    return out


def analyze_source(source: str, path: str,
                   rules: Optional[Iterable[str]] = None,
                   respect_scope: bool = True) -> List[Finding]:
    """Run the (selected) checkers over one module's source text.

    ``path`` may be a virtual ``repro/...`` path: scoped rules key off it,
    so tests can analyze fixture snippets as if they lived in the tree.
    ``respect_scope=False`` forces every checker to run regardless of its
    ``applies`` scoping.
    """
    try:
        ctx = ModuleContext(source, path)
    except SyntaxError as e:
        return [Finding(path=module_path(path), line=e.lineno or 1,
                        rule="RPA000", message=f"syntax error: {e.msg}")]
    findings: List[Finding] = []
    for checker in all_checkers(rules):
        if respect_scope and not checker.applies(ctx):
            continue
        for f in checker.check(ctx):
            if not ctx.suppressed(f.line, f.rule):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings


def analyze_file(path: str,
                 rules: Optional[Iterable[str]] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        return analyze_source(fh.read(), path, rules=rules)


def iter_python_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted, deduplicated .py file list."""
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs.sort()
                dirs[:] = [d for d in dirs if d != "__pycache__"]
                out.extend(os.path.join(root, f)
                           for f in sorted(files) if f.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
    seen, uniq = set(), []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def analyze_paths(paths: Sequence[str],
                  rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Analyze every .py file under ``paths``; deterministic order."""
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(analyze_file(f, rules=rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: Optional[str]) -> frozenset:
    """Fingerprint set from a baseline JSON file (missing/None -> empty)."""
    if path is None or not os.path.exists(path):
        return frozenset()
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return frozenset(
        f"{e['path']}::{e['rule']}::{e['message']}"
        for e in data.get("findings", []))


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    entries = sorted(
        {(f.path, f.rule, f.message) for f in findings})
    data = {
        "comment": "grandfathered repro.analysis findings; regenerate with "
                   "`python -m repro.analysis --write-baseline`",
        "findings": [{"path": p, "rule": r, "message": m}
                     for p, r, m in entries],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=1, sort_keys=True)
        fh.write("\n")


def split_baselined(findings: Sequence[Finding], baseline: frozenset
                    ) -> Tuple[List[Finding], List[Finding]]:
    """(new, grandfathered) partition of ``findings`` by fingerprint."""
    new = [f for f in findings if f.fingerprint not in baseline]
    old = [f for f in findings if f.fingerprint in baseline]
    return new, old
