"""Project-specific checkers enforcing the repo's byte-parity invariants.

Each rule encodes an invariant that otherwise lives only in reviewers'
heads and after-the-fact parity tests:

* **RPA001 codec-protocol conformance** — every ``IdCodec`` subclass
  statically defines the full ``encode/decode/size_bits`` surface with
  the registry's signatures (``gather`` may inherit the random-access
  default), and hot-path modules never ``hasattr``-duck-type an index:
  the codec matrix and the service seam are *contracts*, checked at the
  source, not probed at runtime.
* **RPA002 lock discipline** — in executor-backed services, methods that
  run on the thread pool (statically: targets of ``self._pool.submit``)
  may only mutate ``self`` state or touch shard workers under the owning
  ``self._lock``/``self._locks[...]`` ``with`` block, and state they
  share with caller-thread methods must be locked on both sides.
* **RPA003 serialization determinism** — container writers (RIDX/RIVF
  modules and any ``pack_*``/``*_blobs``/``*_sections`` function) must
  not iterate sets or dict views unsorted, nor call wall-clock/random
  sources: the byte stream must be a pure function of the index.
* **RPA004 overflow/width contracts** — a ``<<`` by >= 32 bits on a
  non-literal operand (merge keys, ANS heads) needs an explicit bound
  check (``raise OverflowError`` / compare against ``1 << BITS``) or a
  uint64 cast in the same function, generalizing ``pack_merge_keys``.
* **RPA005 jit/scan purity** — functions handed to ``jax.jit`` or
  ``pl.pallas_call`` under ``repro/kernels/`` and the scan engines must
  stay traceable: no host prints, ``.item()``/``tolist()``, Python
  scalar coercions, host-``np`` calls (silent constant-folding),
  wall-clock reads, or Python-side mutation.
* **RPA006 broad-except hygiene** — ``except Exception`` (or bare
  ``except``) only in the failure-harvesting allowlist, and such
  handlers must record the failure; everywhere else the concrete
  failure types must be named.
* **RPA007 spec-grammar/docs drift** — the option keys
  ``repro.api.spec`` actually parses (``KNOWN_OPTION_KEYS``) and the
  keys documented in the ``spec-grammar`` block of
  ``docs/architecture.md`` must match exactly, both directions: the
  factory-string grammar is user-facing API and the docs page is its
  normative reference.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Set, Tuple

from .core import Checker, Finding, ModuleContext, register

__all__ = [
    "CodecProtocolChecker", "LockDisciplineChecker",
    "SerializationDeterminismChecker", "WidthContractChecker",
    "JitPurityChecker", "BroadExceptChecker", "SpecGrammarDriftChecker",
]


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def dotted(node: Optional[ast.AST]) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains (through subscripts); else None."""
    parts: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        else:
            return None


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.A`` / ``self.A[...]`` -> ``A``."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ---------------------------------------------------------------------------
# RPA001 — codec-protocol conformance / no hasattr duck-typing
# ---------------------------------------------------------------------------

@register
class CodecProtocolChecker(Checker):
    rule = "RPA001"
    title = "codec-protocol conformance"

    #: method -> positional signature after ``self`` (extras need defaults)
    SURFACE = {
        "encode": ("ids", "universe"),
        "decode": ("blob", "universe"),
        "size_bits": ("blob",),
        "gather": ("blob", "offsets"),
    }
    #: must be statically defined on every registered codec class
    REQUIRED = ("encode", "decode", "size_bits")
    #: modules where hasattr duck-typing is a hot-path hazard
    HOT_PREFIXES = ("repro/ann/", "repro/api/", "repro/serve/",
                    "repro/shard/", "repro/core/")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                out.extend(self._check_class(ctx, node))
        if ctx.path.startswith(self.HOT_PREFIXES):
            for node in ast.walk(ctx.tree):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id == "hasattr"):
                    out.append(self.finding(
                        ctx, node,
                        "hasattr duck-typing on the hot path; use an "
                        "isinstance/protocol check from repro.api.protocol"))
        return out

    def _check_class(self, ctx: ModuleContext,
                     node: ast.ClassDef) -> List[Finding]:
        if not any((dotted(b) or "").split(".")[-1] == "IdCodec"
                   for b in node.bases):
            return []
        out: List[Finding] = []
        methods = {s.name: s for s in node.body
                   if isinstance(s, ast.FunctionDef)}
        for name in self.REQUIRED:
            if name not in methods:
                out.append(self.finding(
                    ctx, node,
                    f"codec class {node.name} must statically define "
                    f"{name}() (no runtime duck-typing on the decode path)"))
        for name, expected in self.SURFACE.items():
            fn = methods.get(name)
            if fn is None:
                continue
            bad = self._signature_mismatch(fn, expected)
            if bad:
                out.append(self.finding(
                    ctx, fn,
                    f"codec method {node.name}.{name}() signature "
                    f"incompatible with the IdCodec contract: {bad}"))
        return out

    @staticmethod
    def _signature_mismatch(fn: ast.FunctionDef,
                            expected: Tuple[str, ...]) -> Optional[str]:
        a = fn.args
        if a.vararg is not None or a.kwarg is not None:
            return None                      # pass-through signature: accept
        names = [arg.arg for arg in a.posonlyargs + a.args]
        if not names or names[0] != "self":
            return "first parameter must be self"
        names = names[1:]
        want = list(expected)
        if len(names) < len(want):
            return (f"expected parameters {tuple(want)}, got {tuple(names)}")
        if names[:len(want)] != want:
            return (f"expected parameters {tuple(want)}, got {tuple(names)}")
        extras = len(names) - len(want)
        if extras > len(a.defaults):
            return ("extra parameters beyond the contract must carry "
                    "defaults")
        return None


# ---------------------------------------------------------------------------
# RPA002 — lock discipline in executor-backed services
# ---------------------------------------------------------------------------

_MUTATORS = frozenset({
    "append", "appendleft", "extend", "insert", "add", "update",
    "setdefault", "pop", "popleft", "popitem", "remove", "discard",
    "clear", "sort",
})


@register
class LockDisciplineChecker(Checker):
    rule = "RPA002"
    title = "lock discipline / race detection"

    def applies(self, ctx: ModuleContext) -> bool:
        return "ThreadPoolExecutor" in ctx.source

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ctx.tree.body:
            if isinstance(node, ast.ClassDef):
                out.extend(self._check_class(ctx, node))
        return out

    def _check_class(self, ctx: ModuleContext,
                     cls: ast.ClassDef) -> List[Finding]:
        executor_methods = self._executor_methods(cls)
        if not executor_methods:
            return []
        # (method, attr, node, locked) for every self-attribute write, plus
        # worker touches (attr None) in executor methods
        writes: List[Tuple[str, Optional[str], ast.AST, bool]] = []
        for fn in cls.body:
            if isinstance(fn, ast.FunctionDef):
                self._scan(fn, fn.body, locked=False, writes=writes,
                           aliases=set(),
                           on_executor=fn.name in executor_methods)
        exec_attrs = {attr for m, attr, _, _ in writes
                      if m in executor_methods and attr is not None}
        out: List[Finding] = []
        for method, attr, node, locked in writes:
            if locked:
                continue
            if method in executor_methods:
                what = (f"self.{attr}" if attr is not None
                        else "a shard worker")
                out.append(self.finding(
                    ctx, node,
                    f"{cls.name}.{method} runs on the executor but mutates "
                    f"{what} outside a `with self._lock(s)` block"))
            elif attr in exec_attrs and method != "__init__":
                # __init__ runs before the object is published to the pool
                out.append(self.finding(
                    ctx, node,
                    f"self.{attr} is also mutated on the executor; this "
                    f"write in {cls.name}.{method} must hold the owning "
                    "self._lock(s)"))
        return out

    @staticmethod
    def _executor_methods(cls: ast.ClassDef) -> Set[str]:
        targets: Set[str] = set()
        for node in ast.walk(cls):
            if (isinstance(node, ast.Call)
                    and dotted(node.func) == "self._pool.submit"
                    and node.args):
                name = dotted(node.args[0])
                if name and name.startswith("self."):
                    targets.add(name.split(".", 1)[1])
        return targets

    @classmethod
    def _is_lock_ctx(cls, expr: ast.AST) -> bool:
        for node in ast.walk(expr):
            name = dotted(node)
            if name is not None and name.startswith("self._lock"):
                return True
        return False

    @classmethod
    def _scan(cls, fn: ast.FunctionDef, stmts, locked: bool,
              writes: List, aliases: Set[str], on_executor: bool) -> None:
        for stmt in stmts:
            if isinstance(stmt, ast.With):
                inner = locked or any(cls._is_lock_ctx(i.context_expr)
                                      for i in stmt.items)
                cls._scan(fn, stmt.body, inner, writes, aliases, on_executor)
                continue
            if isinstance(stmt, (ast.If, ast.For, ast.While)):
                cls._scan(fn, stmt.body, locked, writes, aliases, on_executor)
                cls._scan(fn, stmt.orelse, locked, writes, aliases,
                          on_executor)
                continue
            if isinstance(stmt, ast.Try):
                for blk in (stmt.body, stmt.orelse, stmt.finalbody):
                    cls._scan(fn, blk, locked, writes, aliases, on_executor)
                for h in stmt.handlers:
                    cls._scan(fn, h.body, locked, writes, aliases,
                              on_executor)
                continue
            cls._scan_stmt(fn, stmt, locked, writes, aliases, on_executor)

    @classmethod
    def _scan_stmt(cls, fn: ast.FunctionDef, stmt: ast.stmt, locked: bool,
                   writes: List, aliases: Set[str],
                   on_executor: bool) -> None:
        # worker aliasing: svc = self._workers[s]
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and isinstance(stmt.value, ast.Subscript) \
                and dotted(stmt.value.value) == "self._workers":
            aliases.add(stmt.targets[0].id)
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                writes.append((fn.name, attr, t, locked))
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not isinstance(func, ast.Attribute):
                continue
            # mutating call on self.<attr> / self.<attr>[...]
            if func.attr in _MUTATORS:
                attr = _self_attr(func.value)
                if attr is not None:
                    writes.append((fn.name, attr, node, locked))
            # any call through a shard worker while on the executor
            if on_executor:
                base = func.value
                if (isinstance(base, ast.Subscript)
                        and dotted(base.value) == "self._workers") or (
                        isinstance(base, ast.Name) and base.id in aliases):
                    writes.append((fn.name, None, node, locked))


# ---------------------------------------------------------------------------
# RPA003 — serialization determinism in container writers
# ---------------------------------------------------------------------------

_NONDET_CALLS = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.perf_counter",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow", "date.today",
    "os.urandom", "uuid.uuid1", "uuid.uuid4", "os.getpid",
})
_NONDET_PREFIXES = ("random.", "np.random.", "numpy.random.")
_UNORDERED_FS = frozenset({
    "os.listdir", "os.scandir", "glob.glob", "glob.iglob",
})


@register
class SerializationDeterminismChecker(Checker):
    rule = "RPA003"
    title = "bitstream determinism"

    MODULES = ("repro/core/container.py", "repro/api/container.py")

    def applies(self, ctx: ModuleContext) -> bool:
        return ctx.path in self.MODULES or any(
            self._is_writer_name(fn.name) for fn in _functions(ctx.tree))

    @staticmethod
    def _is_writer_name(name: str) -> bool:
        return ("pack_" in name or name.endswith("_blobs")
                or name.endswith("_sections"))

    def check(self, ctx: ModuleContext) -> List[Finding]:
        if ctx.path in self.MODULES:
            scopes: List[ast.AST] = [ctx.tree]
        else:
            scopes = [fn for fn in _functions(ctx.tree)
                      if self._is_writer_name(fn.name)]
        out: List[Finding] = []
        for scope in scopes:
            out.extend(self._check_scope(ctx, scope))
        return out

    def _check_scope(self, ctx: ModuleContext,
                     scope: ast.AST) -> List[Finding]:
        out: List[Finding] = []
        sorted_args = {
            id(arg)
            for node in ast.walk(scope)
            if isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name) and node.func.id == "sorted"
            for arg in node.args
        }
        iters = []
        for node in ast.walk(scope):
            if isinstance(node, ast.For):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend(g.iter for g in node.generators)
        for it in iters:
            reason = self._unordered_iter(it)
            if reason and id(it) not in sorted_args:
                out.append(self.finding(
                    ctx, it,
                    f"unsorted iteration over {reason} in a serialization "
                    "path; ordering must be explicit or the byte stream "
                    "can drift"))
        for node in ast.walk(scope):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name is None:
                continue
            if name in _NONDET_CALLS or name.startswith(_NONDET_PREFIXES):
                out.append(self.finding(
                    ctx, node,
                    f"nondeterministic call {name}() inside a serialization "
                    "path; the byte stream must be a pure function of the "
                    "index"))
            elif ((name in _UNORDERED_FS or name.endswith(".iterdir"))
                  and id(node) not in sorted_args):
                out.append(self.finding(
                    ctx, node,
                    f"{name}() returns OS-ordered entries; wrap in "
                    "sorted(...) inside serialization paths"))
        return out

    @staticmethod
    def _unordered_iter(it: ast.AST) -> Optional[str]:
        if isinstance(it, ast.Set):
            return "a set literal"
        if isinstance(it, ast.Call):
            if isinstance(it.func, ast.Name) and it.func.id in ("set",
                                                                "frozenset"):
                return f"{it.func.id}(...)"
            if isinstance(it.func, ast.Attribute) and it.func.attr in (
                    "keys", "values", "items"):
                return f".{it.func.attr}() of a dict"
        return None


# ---------------------------------------------------------------------------
# RPA004 — overflow / width contracts on wide shifts
# ---------------------------------------------------------------------------

@register
class WidthContractChecker(Checker):
    rule = "RPA004"
    title = "overflow/width contracts"

    WIDE_BITS = 32

    def check(self, ctx: ModuleContext) -> List[Finding]:
        consts = self._module_consts(ctx.tree)
        out: List[Finding] = []
        # map each wide shift to its nearest enclosing function (or module)
        scopes: List[ast.AST] = [ctx.tree] + list(_functions(ctx.tree))
        seen: Set[int] = set()
        for scope in reversed(scopes):        # innermost functions last
            for node in ast.walk(scope):
                if id(node) in seen or not self._is_wide_shift(node, consts):
                    continue
                seen.add(id(node))
                if scope is not ctx.tree and node is scope:
                    continue
                if not self._guarded(scope, node, consts):
                    amount = self._shift_amount(node.right, consts)
                    out.append(self.finding(
                        ctx, node,
                        f"<< {amount} bit-packing without an explicit bound "
                        "check (raise OverflowError / compare against "
                        "1 << BITS) or uint64 cast in the same scope; a "
                        "silent wrap corrupts packed keys"))
        return out

    @classmethod
    def _module_consts(cls, tree: ast.Module) -> Dict[str, int]:
        consts: Dict[str, int] = {}
        for stmt in tree.body:
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                val = cls._fold(stmt.value, consts)
                if isinstance(val, int):
                    consts[stmt.targets[0].id] = val
        return consts

    @classmethod
    def _fold(cls, node: ast.AST, consts: Dict[str, int]) -> Optional[int]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return node.value
        if isinstance(node, ast.Name):
            return consts.get(node.id)
        if isinstance(node, ast.BinOp):
            left = cls._fold(node.left, consts)
            right = cls._fold(node.right, consts)
            if left is None or right is None:
                return None
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.LShift):
                return left << right
            if isinstance(node.op, ast.BitOr):
                return left | right
            return None
        if isinstance(node, ast.Call) and len(node.args) == 1:
            name = dotted(node.func) or ""
            if name.split(".")[-1] in ("uint64", "int64", "uint32", "int"):
                return cls._fold(node.args[0], consts)
        return None

    @classmethod
    def _shift_amount(cls, right: ast.AST,
                      consts: Dict[str, int]) -> Optional[int]:
        return cls._fold(right, consts)

    @classmethod
    def _is_wide_shift(cls, node: ast.AST, consts: Dict[str, int]) -> bool:
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.LShift)):
            return False
        if isinstance(node.left, ast.Constant):
            return False                      # python-int literal: no wrap
        amount = cls._shift_amount(node.right, consts)
        return amount is not None and amount >= cls.WIDE_BITS

    @classmethod
    def _guarded(cls, scope: ast.AST, shift: ast.BinOp,
                 consts: Dict[str, int]) -> bool:
        # the shifted operand itself carries a uint64 cast
        left_name = dotted(shift.left)
        if left_name is not None and left_name.split(".")[-1] == "uint64":
            return True
        if isinstance(shift.left, ast.Call):
            fname = (dotted(shift.left.func) or "").split(".")[-1]
            if fname in ("uint64", "int64"):
                return True
        for node in ast.walk(scope):
            if isinstance(node, ast.Raise):
                exc = node.exc
                name = dotted(exc.func) if isinstance(exc, ast.Call) \
                    else dotted(exc)
                if name and "OverflowError" in name:
                    return True
            if isinstance(node, ast.Compare):
                for part in [node.left] + list(node.comparators):
                    if any(isinstance(sub, ast.BinOp)
                           and isinstance(sub.op, ast.LShift)
                           for sub in ast.walk(part)):
                        return True
            if isinstance(node, ast.Call):
                name = (dotted(node.func) or "").split(".")[-1]
                if name == "uint64":
                    return True
                if name in ("asarray", "astype") and any(
                        (dotted(a) or "").split(".")[-1] == "uint64"
                        for a in node.args):
                    return True
        return False


# ---------------------------------------------------------------------------
# RPA005 — jit / pallas purity
# ---------------------------------------------------------------------------

@register
class JitPurityChecker(Checker):
    rule = "RPA005"
    title = "jit/scan purity"

    MODULES = ("repro/ann/scan.py", "repro/ann/graph_scan.py")

    def applies(self, ctx: ModuleContext) -> bool:
        return (ctx.path.startswith("repro/kernels/")
                or ctx.path in self.MODULES)

    def check(self, ctx: ModuleContext) -> List[Finding]:
        kernel_names = self._pallas_kernel_names(ctx.tree)
        out: List[Finding] = []
        self._visit(ctx, ctx.tree.body, kernel_names, restricted=False,
                    out=out)
        return out

    @staticmethod
    def _pallas_kernel_names(tree: ast.AST) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call) and node.args
                    and (dotted(node.func) or "").split(".")[-1]
                    == "pallas_call"
                    and isinstance(node.args[0], ast.Name)):
                names.add(node.args[0].id)
        return names

    @staticmethod
    def _is_jitted(fn: ast.FunctionDef) -> bool:
        for dec in fn.decorator_list:
            name = dotted(dec)
            if name is not None and name.split(".")[-1] in ("jit", "vmap"):
                return True
            if isinstance(dec, ast.Call):
                fname = (dotted(dec.func) or "").split(".")[-1]
                if fname in ("jit", "vmap"):
                    return True
                if fname == "partial" and any(
                        (dotted(a) or "").split(".")[-1] in ("jit", "vmap")
                        for a in dec.args):
                    return True
        return False

    def _visit(self, ctx: ModuleContext, stmts, kernel_names: Set[str],
               restricted: bool, out: List[Finding]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = (restricted or stmt.name in kernel_names
                         or self._is_jitted(stmt))
                if inner:
                    self._check_traced(ctx, stmt, out)
                else:
                    self._visit(ctx, stmt.body, kernel_names, False, out)
            elif isinstance(stmt, ast.ClassDef):
                self._visit(ctx, stmt.body, kernel_names, restricted, out)
            elif hasattr(stmt, "body"):
                self._visit(ctx, stmt.body, kernel_names, restricted, out)
                for blk in ("orelse", "finalbody"):
                    self._visit(ctx, getattr(stmt, blk, []), kernel_names,
                                restricted, out)
                for h in getattr(stmt, "handlers", []):
                    self._visit(ctx, h.body, kernel_names, restricted, out)

    def _check_traced(self, ctx: ModuleContext, fn: ast.FunctionDef,
                      out: List[Finding]) -> None:
        where = f"traced function {fn.name}()"
        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                out.append(self.finding(
                    ctx, node, f"global/nonlocal mutation inside {where}: "
                    "traced code must be pure"))
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute):
                        out.append(self.finding(
                            ctx, node,
                            f"Python-side attribute mutation inside {where}: "
                            "side effects are silently dropped under jit"))
            elif isinstance(node, ast.Call):
                name = dotted(node.func)
                if isinstance(node.func, ast.Name) and node.func.id == \
                        "print":
                    out.append(self.finding(
                        ctx, node, f"host print() inside {where}: runs at "
                        "trace time only"))
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in ("float", "int", "bool") \
                        and node.args \
                        and not all(isinstance(a, ast.Constant)
                                    for a in node.args):
                    out.append(self.finding(
                        ctx, node,
                        f"{node.func.id}() scalar coercion inside {where}: "
                        "forces a host sync / fails under tracing"))
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr in ("item", "tolist"):
                    out.append(self.finding(
                        ctx, node,
                        f".{node.func.attr}() inside {where}: forces a host "
                        "sync / fails under tracing"))
                elif name is not None and name.startswith(("np.",
                                                           "numpy.")):
                    out.append(self.finding(
                        ctx, node,
                        f"host-numpy call {name}() inside {where}: silently "
                        "constant-folds at trace time; use jnp"))
                elif name is not None and name.startswith("time."):
                    out.append(self.finding(
                        ctx, node,
                        f"wall-clock read {name}() inside {where}: traced "
                        "code must be pure"))


# ---------------------------------------------------------------------------
# RPA006 — broad-except hygiene
# ---------------------------------------------------------------------------

@register
class BroadExceptChecker(Checker):
    rule = "RPA006"
    title = "broad-except hygiene"

    #: failure-harvesting modules where `except Exception` is the contract
    ALLOWLIST = ("repro/launch/dryrun.py",)
    #: an allowlisted handler must reference one of these (record the fault)
    RECORD_MARKERS = ("error", "stats", "fault", "partial", "record", "log")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        allowlisted = ctx.path in self.ALLOWLIST
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not self._is_broad(node.type):
                continue
            if allowlisted:
                if not self._records(node):
                    out.append(self.finding(
                        ctx, node,
                        "allowlisted broad except must record the failure "
                        "(stats/error/fault log), not swallow it"))
            else:
                out.append(self.finding(
                    ctx, node,
                    "broad `except Exception` outside the fault-handling "
                    "allowlist; catch the concrete failure types (e.g. "
                    "ShardTimeout/ShardDead/TimeoutError) and record into "
                    "stats"))
        return out

    @staticmethod
    def _is_broad(type_node: Optional[ast.AST]) -> bool:
        if type_node is None:
            return True
        names = ([dotted(e) for e in type_node.elts]
                 if isinstance(type_node, ast.Tuple) else [dotted(type_node)])
        return any(n is not None
                   and n.split(".")[-1] in ("Exception", "BaseException")
                   for n in names)

    def _records(self, handler: ast.ExceptHandler) -> bool:
        for node in ast.walk(handler):
            words: List[str] = []
            if isinstance(node, ast.Name):
                words.append(node.id)
            elif isinstance(node, ast.Attribute):
                words.append(node.attr)
            elif isinstance(node, ast.Constant) and isinstance(node.value,
                                                               str):
                words.append(node.value)
            for w in words:
                lw = w.lower()
                if any(m in lw for m in self.RECORD_MARKERS):
                    return True
        return False


# ---------------------------------------------------------------------------
# RPA007 — spec-grammar / docs drift
# ---------------------------------------------------------------------------

_GRAMMAR_FENCE_RE = re.compile(
    r"```[^\n`]*spec-grammar[^\n`]*\n(.*?)\n```", re.DOTALL)
_GRAMMAR_KEY_RE = re.compile(r"^\s*([a-z_]+)\s*=\s", re.MULTILINE)


@register
class SpecGrammarDriftChecker(Checker):
    rule = "RPA007"
    title = "spec-grammar/docs drift"

    MODULE = "repro/api/spec.py"
    DOC = os.path.join("docs", "architecture.md")
    KEYS_NAME = "KNOWN_OPTION_KEYS"

    def applies(self, ctx: ModuleContext) -> bool:
        return ctx.path == self.MODULE

    def check(self, ctx: ModuleContext) -> List[Finding]:
        code_keys = self._option_keys(ctx.tree)
        if code_keys is None:
            return [self.finding(
                ctx, 1,
                f"spec module must define {self.KEYS_NAME} as a "
                "module-level tuple of string literals (the grammar keys "
                "docs/architecture.md documents)")]
        line, keys = code_keys
        doc_path = self._locate_doc(ctx.fs_path)
        if doc_path is None:
            return [self.finding(
                ctx, line,
                f"cannot locate {self.DOC} above {ctx.fs_path}; the "
                "factory-string grammar must have a docs page")]
        with open(doc_path, encoding="utf-8") as fh:
            doc_keys = self._doc_keys(fh.read())
        if doc_keys is None:
            return [self.finding(
                ctx, line,
                f"{self.DOC} has no ```spec-grammar fenced block; the "
                "documented grammar is what RPA007 checks against")]
        out: List[Finding] = []
        for key in keys:
            if key not in doc_keys:
                out.append(self.finding(
                    ctx, line,
                    f"spec option {key!r} is parsed but missing from the "
                    f"spec-grammar block in {self.DOC}"))
        for key in doc_keys:
            if key not in keys:
                out.append(self.finding(
                    ctx, line,
                    f"spec option {key!r} is documented in the "
                    f"spec-grammar block of {self.DOC} but not parsed "
                    f"({self.KEYS_NAME})"))
        return out

    @classmethod
    def _option_keys(cls, tree: ast.Module
                     ) -> Optional[Tuple[int, Tuple[str, ...]]]:
        for stmt in tree.body:
            if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and stmt.targets[0].id == cls.KEYS_NAME):
                continue
            if not isinstance(stmt.value, (ast.Tuple, ast.List)):
                return None
            keys: List[str] = []
            for elt in stmt.value.elts:
                if not (isinstance(elt, ast.Constant)
                        and isinstance(elt.value, str)):
                    return None
                keys.append(elt.value)
            return stmt.lineno, tuple(keys)
        return None

    @classmethod
    def _locate_doc(cls, fs_path: str) -> Optional[str]:
        d = os.path.dirname(os.path.abspath(fs_path))
        while True:
            cand = os.path.join(d, cls.DOC)
            if os.path.isfile(cand):
                return cand
            parent = os.path.dirname(d)
            if parent == d:
                return None
            d = parent

    @staticmethod
    def _doc_keys(doc: str) -> Optional[Tuple[str, ...]]:
        m = _GRAMMAR_FENCE_RE.search(doc)
        if m is None:
            return None
        return tuple(_GRAMMAR_KEY_RE.findall(m.group(1)))
