"""Project-specific static analysis for the repro codebase.

Run with ``python -m repro.analysis [paths]``.  The pass enforces the
invariants behind byte-reproducible compression and the concurrent
serving path at the *source* level:

==========  ===============================================================
RPA001      codec-protocol conformance (full ``encode/decode/size_bits``
            surface on every ``IdCodec``; no ``hasattr`` duck-typing on
            the hot path)
RPA002      lock discipline in executor-backed services
RPA003      serialization determinism in container/blob writers
RPA004      overflow/width contracts on wide bit-pack shifts
RPA005      jit/Pallas purity in traced functions
RPA006      broad-except hygiene (allowlist + must record the failure)
==========  ===============================================================

Suppress one line with ``# repro: ignore[RPA001]`` (or a bare
``# repro: ignore``); grandfather whole findings in
``analysis_baseline.json`` (``--write-baseline``).
"""

from .core import (CHECKERS, Checker, Finding, ModuleContext, all_checkers,
                   analyze_file, analyze_paths, analyze_source,
                   load_baseline, module_path, split_baselined,
                   write_baseline)
from .cli import main

__all__ = [
    "CHECKERS", "Checker", "Finding", "ModuleContext", "all_checkers",
    "analyze_file", "analyze_paths", "analyze_source", "load_baseline",
    "module_path", "split_baselined", "write_baseline", "main",
]
