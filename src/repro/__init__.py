"""repro: JAX framework reproducing 'Lossless Compression of Vector IDs for
Approximate Nearest Neighbor Search' (Severo et al., 2025) with a multi-pod
LM training/serving runtime over 10 assigned architectures."""

__version__ = "0.1.0"
