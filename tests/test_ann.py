"""ANN substrate tests: kmeans, PQ, IVF (with every id codec), graph index."""

import numpy as np
import pytest

from repro.ann.graph import GraphIndex, build_hnsw, build_nsg, knn_graph
from repro.ann.ivf import IVFIndex
from repro.ann.kmeans import assign, kmeans
from repro.ann.pq import ProductQuantizer
from repro.data.synthetic import make_dataset


@pytest.fixture(scope="module")
def small_data():
    base, queries = make_dataset("deep-like", 5000, 50, seed=0)
    return base, queries


def _exact_topk(base, queries, k):
    d = (
        np.sum(queries**2, 1, keepdims=True)
        - 2 * queries @ base.T
        + np.sum(base**2, 1)[None]
    )
    return np.argsort(d, axis=1)[:, :k]


def test_kmeans_reduces_quantization_error(small_data):
    base, _ = small_data
    c1 = base[:64].copy()
    c10 = kmeans(base, 64, iters=10)
    def qerr(c):
        a = assign(base, c)
        return float(np.mean(np.sum((base - c[a]) ** 2, axis=1)))
    assert qerr(c10) < qerr(c1) * 0.9


def test_pq_roundtrip_reduces_error(small_data):
    base, _ = small_data
    pq = ProductQuantizer(m=8, bits=8).train(base, iters=3)
    codes = pq.encode(base)
    rec = pq.decode(codes)
    err = np.mean(np.sum((base - rec) ** 2, 1))
    ref = np.mean(np.sum((base - base.mean(0)) ** 2, 1))
    assert err < 0.5 * ref


def test_pq_adc_consistent(small_data):
    base, queries = small_data
    pq = ProductQuantizer(m=8, bits=8).train(base, iters=3)
    codes = pq.encode(base)
    t = pq.adc_tables(queries[:1])[0]
    d_adc = pq.adc_score(codes, t)
    d_true = np.sum((pq.decode(codes) - queries[0]) ** 2, axis=1)
    np.testing.assert_allclose(d_adc, d_true, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("codec", ["compact", "ef", "roc", "gap_ans", "wt", "wt1"])
def test_ivf_search_identical_across_codecs(small_data, codec):
    """The paper's central claim: compression is LOSSLESS — search results
    are bit-identical whatever the id codec."""
    base, queries = small_data
    ref_idx = IVFIndex(nlist=32, id_codec="unc64").build(base, seed=1)
    ids_ref, d_ref, _ = ref_idx.search(queries[:10], nprobe=8, topk=5)
    idx = IVFIndex(nlist=32, id_codec=codec).build(base, seed=1)
    ids, d, _ = idx.search(queries[:10], nprobe=8, topk=5)
    np.testing.assert_array_equal(ids, ids_ref)
    np.testing.assert_allclose(d, d_ref, rtol=1e-5)


def test_ivf_recall_reasonable(small_data):
    base, queries = small_data
    idx = IVFIndex(nlist=32, id_codec="roc").build(base, seed=1)
    ids, _, _ = idx.search(queries, nprobe=8, topk=10)
    gt = _exact_topk(base, queries, 1)
    recall = np.mean([gt[i, 0] in ids[i] for i in range(len(queries))])
    assert recall > 0.8


def test_ivf_pq_with_polya_codes(small_data):
    base, queries = small_data
    pq = ProductQuantizer(m=8, bits=8)
    idx = IVFIndex(nlist=16, id_codec="roc", pq=pq, code_codec="polya").build(base, seed=1)
    bpe = idx.code_bits_per_element()
    assert 0 < bpe <= 8.5
    ids, _, _ = idx.search(queries[:5], nprobe=8, topk=5)
    assert ids.shape == (5, 5)


def test_ivf_compression_beats_compact(small_data):
    base, _ = small_data
    idx = IVFIndex(nlist=16, id_codec="roc").build(base, seed=1)
    compact = np.ceil(np.log2(len(base)))
    assert idx.bits_per_id() < compact - 3  # large clusters -> big savings


def test_knn_graph_exact():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((300, 8)).astype(np.float32)
    nn = knn_graph(x, 5)
    d = np.sum((x[:, None] - x[None]) ** 2, -1)
    np.fill_diagonal(d, np.inf)
    ref = np.argsort(d, axis=1)[:, :5]
    # sets must match (ties may permute)
    match = np.mean([set(nn[i]) == set(ref[i]) for i in range(300)])
    assert match > 0.95


@pytest.mark.parametrize("builder", [build_nsg, build_hnsw])
def test_graph_search_recall(small_data, builder):
    base, queries = small_data
    base, queries = base[:2000], queries[:30]
    adj = builder(base, 16)
    gi = GraphIndex(id_codec="roc").build(base, adj)
    ids, _, st = gi.search(queries, ef=32, topk=5)
    gt = _exact_topk(base, queries, 1)
    recall = np.mean([gt[i, 0] in ids[i] for i in range(len(queries))])
    assert recall > 0.7
    # uniform stats shape (satellite of the api redesign): graph searches
    # report visited/decode counters like the IVF engine does, plus the
    # batched engine's step counters ("graph-xla" / "graph-pallas")
    assert st.engine.startswith("graph-")
    assert st.visited > 0 and st.ndis > 0 and st.wall_s > 0
    assert 0 < st.decodes <= st.visited
    assert st.steps > 0 and st.frontier_size >= st.steps
    assert st.dedup_hits >= 0


def test_graph_codecs_identical_results(small_data):
    base, queries = small_data
    base, queries = base[:1000], queries[:10]
    adj = build_nsg(base, 12)
    ref = GraphIndex(id_codec="unc32").build(base, adj)
    ids_ref, _, _ = ref.search(queries, ef=16, topk=5)
    for codec in ["roc", "ef", "gap_ans"]:
        gi = GraphIndex(id_codec=codec).build(base, adj)
        ids, _, _ = gi.search(queries, ef=16, topk=5)
        np.testing.assert_array_equal(ids, ids_ref)
