"""RIDX v2: every factory spec round-trips losslessly through save/load.

The acceptance bar of the api redesign: for the full IVF codec × payload
matrix and both graph kinds, ``load(save(index))`` returns bit-identical
search results (ids AND distances), the spec string survives, and the
``id_bits`` bookkeeping matches the pre-save index exactly (online blobs
are deterministic re-encodes of the decoded lists).
"""

import numpy as np
import pytest

import jax

from repro.ann.kmeans import kmeans
from repro.ann.pq import ProductQuantizer
from repro.api import index_factory, load_index, save_index
from repro.api.container import RIDX_MAGIC, unpack_index

jax.config.update("jax_platforms", "cpu")

ALL_ID_CODECS = ["unc64", "unc32", "compact", "ef", "roc", "gap_ans",
                 "wt", "wt1"]
NLIST = 12
D = 32


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    base = rng.standard_normal((900, D)).astype(np.float32)
    queries = rng.standard_normal((12, D)).astype(np.float32)
    return base, queries


@pytest.fixture(scope="module")
def centroids(data):
    return kmeans(data[0], NLIST, iters=4, seed=1)


@pytest.fixture(scope="module")
def pq(data):
    return ProductQuantizer(m=8, bits=8).train(data[0], iters=3)


@pytest.fixture(scope="module")
def graph_adjs(data):
    from repro.ann.graph import build_hnsw, build_nsg

    base = data[0][:350]
    return {"nsg": build_nsg(base, 8), "hnsw": build_hnsw(base, 8)}


def _roundtrip(idx, queries, search_kw):
    d0, i0, _ = idx.search(queries, **search_kw)
    blob = save_index(idx)
    assert blob[:4] == RIDX_MAGIC
    idx2 = load_index(blob)
    assert idx2.spec == idx.spec
    d1, i1, _ = idx2.search(queries, **search_kw)
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_array_equal(d1, d0)   # exact, not allclose
    return idx2


def _build_ivf(spec, data, centroids, pq):
    idx = index_factory(spec)
    if idx.ivf.pq is not None:
        idx.ivf.pq.codebooks = pq.codebooks  # shared training (test speed)
    return idx.build(data[0], seed=1, centroids=centroids)


@pytest.mark.parametrize("codec", ALL_ID_CODECS)
@pytest.mark.parametrize("payload", ["", ",PQ8x8", ",PQ8x8+polya"])
def test_ivf_matrix_roundtrip(data, centroids, pq, codec, payload):
    spec = (f"IVF{NLIST}"
            + payload.replace("+polya", "")
            + f",ids={codec}"
            + (",codes=polya" if payload.endswith("+polya") else ""))
    idx = _build_ivf(spec, data, centroids, pq)
    idx2 = _roundtrip(idx, data[1], dict(k=7, nprobe=5, engine="xla"))
    # size bookkeeping survives the reload bit-for-bit
    assert idx2.ivf.id_bits() == idx.ivf.id_bits()
    assert idx2.ivf.bits_per_id() == idx.ivf.bits_per_id()
    if payload.endswith("+polya"):
        assert (idx2.ivf.code_bits_per_element()
                == idx.ivf.code_bits_per_element())
    # the reloaded index still matches the per-query oracle
    ids_b, d_b, _ = idx2.ivf.search(data[1], nprobe=5, topk=7, engine="xla")
    ids_r, d_r, _ = idx2.ivf.search_ref(data[1], nprobe=5, topk=7)
    np.testing.assert_array_equal(ids_b, ids_r)
    np.testing.assert_array_equal(d_b, d_r)


@pytest.mark.parametrize("kind", ["nsg", "hnsw"])
@pytest.mark.parametrize("codec", ["roc", "ef"])
def test_graph_roundtrip(data, graph_adjs, kind, codec):
    base = data[0][:350]
    idx = index_factory(f"{kind.upper()}8,ids={codec}").build(
        base, adj=[a.copy() for a in graph_adjs[kind]])
    idx2 = _roundtrip(idx, data[1], dict(k=5, ef=16))
    assert idx2.graph.id_bits() == idx.graph.id_bits()
    assert idx2.graph.entry == idx.graph.entry
    for a, b in zip(idx.graph.adj_raw, idx2.graph.adj_raw):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("graph_codec", ["webgraph", "rec"])
def test_graph_offline_codecs(data, graph_adjs, graph_codec):
    base = data[0][:350]
    idx = index_factory("NSG8,ids=roc").build(
        base, adj=[a.copy() for a in graph_adjs["nsg"]])
    d0, i0, _ = idx.search(data[1], k=5, ef=16)
    blob = save_index(idx, graph_codec=graph_codec)
    idx2 = load_index(blob)
    d1, i1, _ = idx2.search(data[1], k=5, ef=16)
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_array_equal(d1, d0)


def test_flat_roundtrip(data):
    idx = index_factory("Flat").build(data[0])
    idx2 = _roundtrip(idx, data[1], dict(k=9))
    np.testing.assert_array_equal(idx2.vecs, idx.vecs)


def test_options_survive_roundtrip(data, centroids, pq):
    idx = _build_ivf(f"IVF{NLIST},ids=roc,cache_mb=2,engine=xla",
                     data, centroids, pq)
    blob = save_index(idx)
    idx2 = load_index(blob)
    assert idx2.spec == idx.spec
    assert idx2.ivf.decoded_cache.max_bytes == 2 << 20


def test_save_load_file_path(tmp_path, data, centroids, pq):
    idx = _build_ivf(f"IVF{NLIST},ids=ef", data, centroids, pq)
    p = tmp_path / "index.ridx"
    save_index(idx, p)
    idx2 = load_index(p)
    d0, i0, _ = idx.search(data[1], k=5)
    d1, i1, _ = idx2.search(data[1], k=5)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(d0, d1)


def test_container_rejects_garbage():
    with pytest.raises(ValueError):
        unpack_index(b"NOPE" + b"\x00" * 64)


def test_v1_container_still_unpacks(data, centroids, pq):
    """The legacy RIVF v1 blob keeps working alongside RIDX v2."""
    from repro.core.container import pack_ivf, unpack_ivf

    idx = _build_ivf(f"IVF{NLIST},PQ8x8,ids=compact,codes=polya",
                     data, centroids, pq)
    manifest, lists, cents, codes = unpack_ivf(pack_ivf(idx.ivf))
    assert manifest["n"] == len(data[0])
    for k in range(NLIST):
        np.testing.assert_array_equal(lists[k], idx.ivf._lists[k])
    np.testing.assert_array_equal(codes, idx.ivf.codes)
