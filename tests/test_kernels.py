"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles, plus end-to-end roundtrips against the numpy encoders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.vrans import VRans16Encoder, VRans16Decoder
from repro.kernels.pq_adc import pq_adc, pq_adc_ref
from repro.kernels.l2_topk import l2_dist, l2_dist_ref, l2_top1, l2_top1_ref
from repro.kernels.rans_decode import make_tables, rans_decode, rans_decode_ref
from repro.kernels.wt_rank import pack_bits_u32, wt_rank, wt_rank_ref

jax.config.update("jax_platforms", "cpu")


# ---------------------------------------------------------------------------
# pq_adc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [100, 1024, 5000])
@pytest.mark.parametrize("m", [4, 8, 16])
@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32])
def test_pq_adc_matches_ref(n, m, dtype):
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 256, size=(n, m)), dtype=dtype)
    lut = jnp.asarray(rng.random((m, 256), np.float32))
    out = pq_adc(codes, lut)
    ref = pq_adc_ref(codes.astype(jnp.int32), lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_pq_adc_against_numpy_pq():
    from repro.ann.pq import ProductQuantizer

    rng = np.random.default_rng(1)
    x = rng.random((2000, 32), np.float32)
    pq = ProductQuantizer(m=8, bits=8).train(x, iters=2)
    codes = pq.encode(x)
    q = rng.random((1, 32), np.float32)
    table = pq.adc_tables(q)[0]
    ker = np.asarray(pq_adc(jnp.asarray(codes), jnp.asarray(table)))
    ref = pq.adc_score(codes, table)
    np.testing.assert_allclose(ker, ref, rtol=1e-4)


@pytest.mark.parametrize("n", [0, 1, 1023, 1024, 1025])
def test_pq_adc_padding_edges(n):
    """N = 0, N < block, N == block, N not a multiple of BLOCK_N."""
    rng = np.random.default_rng(30)
    codes = jnp.asarray(rng.integers(0, 256, size=(n, 8)), jnp.int32)
    lut = jnp.asarray(rng.random((8, 256), np.float32))
    out = pq_adc(codes, lut)
    assert out.shape == (n,)
    if n:
        ref = pq_adc_ref(codes, lut)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# l2_topk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,k,d", [(64, 100, 32), (300, 1024, 128), (256, 77, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_top1_matches_ref(nq, k, d, dtype):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((nq, d)), dtype=dtype)
    c = jnp.asarray(rng.standard_normal((k, d)), dtype=dtype)
    idx, val = l2_top1(q, c)
    ridx, rval = l2_top1_ref(q.astype(jnp.float32), c.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nq", [0, 1, 255, 256, 257])
def test_l2_top1_padding_edges(nq):
    rng = np.random.default_rng(31)
    q = jnp.asarray(rng.standard_normal((nq, 24)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((77, 24)), jnp.float32)
    idx, val = l2_top1(q, c)
    assert idx.shape == (nq,) and val.shape == (nq,)
    if nq:
        ridx, rval = l2_top1_ref(q, c)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
        np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nq,n", [(3, 100), (64, 512), (65, 513),
                                  (256, 511), (1, 1)])
@pytest.mark.parametrize("d", [16, 128, 130])
def test_l2_dist_matches_ref(nq, n, d):
    """The batched-scan distance-matrix kernel vs the jnp oracle."""
    rng = np.random.default_rng(32)
    q = jnp.asarray(rng.standard_normal((nq, d)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    out = l2_dist(q, c)
    ref = l2_dist_ref(q, c)
    assert out.shape == (nq, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nq,n", [(0, 10), (10, 0), (0, 0)])
def test_l2_dist_empty_edges(nq, n):
    q = jnp.zeros((nq, 8), jnp.float32)
    c = jnp.zeros((n, 8), jnp.float32)
    out = l2_dist(q, c)
    assert out.shape == (nq, n)


# ---------------------------------------------------------------------------
# rans_decode
# ---------------------------------------------------------------------------

def _geom_freqs(alpha: int, r: int) -> np.ndarray:
    f = np.maximum(1, (1 << r) >> (np.arange(alpha) + 1)).astype(np.int64)
    f[0] += (1 << r) - f.sum()
    return f


@pytest.mark.parametrize("r,alpha", [(8, 16), (12, 24), (16, 64)])
@pytest.mark.parametrize("rows", [1, 7, 64])
def test_rans_decode_kernel_roundtrip(r, alpha, rows):
    """encode with the numpy 32/16 coder, decode with the Pallas kernel."""
    rng = np.random.default_rng(3)
    L = 128
    freqs = _geom_freqs(alpha, r)
    starts = np.cumsum(freqs) - freqs
    # skewed symbols so renorm patterns vary per lane
    p = freqs / freqs.sum()
    data = rng.choice(alpha, size=(rows, L), p=p)
    enc = VRans16Encoder(L)
    for t in range(rows - 1, -1, -1):
        enc.push(starts[data[t]], freqs[data[t]], r)
    heads, words = enc.finalize()
    sym_t, freq_t, start_t = make_tables(freqs, r)
    out = rans_decode(jnp.asarray(heads), jnp.asarray(words.astype(np.uint32)),
                      jnp.asarray(sym_t), jnp.asarray(freq_t),
                      jnp.asarray(start_t), rows=rows, r=r)
    np.testing.assert_array_equal(np.asarray(out), data)


def test_rans_decode_kernel_matches_ref_oracle():
    rng = np.random.default_rng(4)
    L, rows, r, alpha = 128, 32, 12, 24
    freqs = _geom_freqs(alpha, r)
    starts = np.cumsum(freqs) - freqs
    p = freqs / freqs.sum()
    data = rng.choice(alpha, size=(rows, L), p=p)
    enc = VRans16Encoder(L)
    for t in range(rows - 1, -1, -1):
        enc.push(starts[data[t]], freqs[data[t]], r)
    heads, words = enc.finalize()
    sym_t, freq_t, start_t = make_tables(freqs, r)
    args = (jnp.asarray(heads), jnp.pad(jnp.asarray(words.astype(np.uint32)), (0, L)),
            jnp.asarray(sym_t), jnp.asarray(freq_t), jnp.asarray(start_t))
    ker = rans_decode(args[0], jnp.asarray(words.astype(np.uint32)),
                      *args[2:], rows=rows, r=r)
    ref = rans_decode_ref(*args, rows=rows, r=r)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


def test_vrans16_numpy_roundtrip():
    rng = np.random.default_rng(5)
    L, rows, r = 16, 200, 10
    data = rng.integers(0, 1 << r, size=(rows, L))
    enc = VRans16Encoder(L)
    for t in range(rows - 1, -1, -1):
        enc.push_uniform(data[t], r)
    heads, words = enc.finalize()
    dec = VRans16Decoder(heads, words)
    for t in range(rows):
        np.testing.assert_array_equal(dec.pop_uniform(r), data[t])


# ---------------------------------------------------------------------------
# wt_rank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 1000, 100_000])
@pytest.mark.parametrize("p", [0.05, 0.5, 0.95])
def test_wt_rank_matches_ref(n, p):
    rng = np.random.default_rng(6)
    bits = (rng.random(n) < p).astype(np.uint8)
    words, super_cum = pack_bits_u32(bits)
    queries = rng.integers(0, n + 1, size=777)
    out = wt_rank(jnp.asarray(words), jnp.asarray(super_cum),
                  jnp.asarray(queries.astype(np.int32)))
    ref = wt_rank_ref(jnp.asarray(bits), jnp.asarray(queries.astype(np.int32)))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
