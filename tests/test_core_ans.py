"""Unit + property tests for the ANS coders (BigANS, StreamANS, VRans)."""

import numpy as np
import pytest

try:  # hypothesis is optional (tests/requirements-test.txt): without it the
    from hypothesis import given, settings, strategies as st
except ImportError:  # properties run over deterministic seeded samples
    from _compat_hypothesis import given, settings, st

from repro.core.ans import BigANS, StreamANS
from repro.core.vrans import VRansDecoder, VRansEncoder


# ---------------------------------------------------------------------------
# BigANS
# ---------------------------------------------------------------------------

def test_bigans_uniform_roundtrip():
    rng = np.random.default_rng(0)
    ns = rng.integers(2, 1000, size=200)
    xs = [int(rng.integers(0, n)) for n in ns]
    ans = BigANS()
    for x, n in zip(xs, ns):
        ans.push_uniform(x, int(n))
    for x, n in zip(reversed(xs), reversed(ns)):
        assert ans.pop_uniform(int(n)) == x
    assert ans.state == 0


def test_bigans_rate_is_exact():
    # k uniform symbols over [256) cost exactly 8k bits (up to the leading
    # symbol's own magnitude)
    ans = BigANS()
    for _ in range(100):
        ans.push_uniform(255, 256)
    assert ans.bits == 800
    ans2 = BigANS()
    for _ in range(100):
        ans2.push_uniform(7, 256)
    assert 792 < ans2.bits <= 800


def test_bigans_pmf_roundtrip():
    rng = np.random.default_rng(1)
    freqs = np.array([3, 1, 5, 7], dtype=np.int64)
    total = int(freqs.sum())
    cums = np.concatenate([[0], np.cumsum(freqs)[:-1]])
    xs = rng.integers(0, 4, size=500)
    ans = BigANS()
    for x in xs:
        ans.push_pmf(int(cums[x]), int(freqs[x]), total)
    for x in reversed(xs):
        cf = ans.pop_cf(total)
        sym = int(np.searchsorted(np.cumsum(freqs), cf, side="right"))
        assert sym == x
        ans.pop_advance(int(cums[sym]), int(freqs[sym]), total)
    assert ans.state == 0


def test_bigans_serialization():
    ans = BigANS()
    for x in [5, 77, 1000]:
        ans.push_uniform(x, 2048)
    raw = ans.tobytes()
    ans2 = BigANS.frombytes(raw)
    assert [ans2.pop_uniform(2048) for _ in range(3)] == [1000, 77, 5]


@given(st.lists(st.integers(0, 2**20 - 1), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_bigans_uniform_property(xs):
    ans = BigANS()
    for x in xs:
        ans.push_uniform(x, 2**20)
    out = [ans.pop_uniform(2**20) for _ in range(len(xs))]
    assert out == list(reversed(xs))
    assert ans.state == 0


# ---------------------------------------------------------------------------
# StreamANS (pow2 totals)
# ---------------------------------------------------------------------------

def test_streamans_roundtrip_mixed_precisions():
    rng = np.random.default_rng(2)
    ops = []
    for _ in range(2000):
        r = int(rng.integers(1, 17))
        total = 1 << r
        f = int(rng.integers(1, total + 1))
        c = int(rng.integers(0, total - f + 1))
        ops.append((c, f, r))
    ans = StreamANS()
    for c, f, r in ops:
        ans.push(c, f, r)
    for c, f, r in reversed(ops):
        if f == (1 << r):
            continue
        cf = ans.pop_cf(r)
        assert c <= cf < c + f
        ans.pop_advance(c, f, r)
    assert ans.head == 1 << 32 and not ans.tail


def test_streamans_rate_close_to_entropy():
    # skewed binary source, p=1/16 -> H ~= 0.337 bits/sym
    rng = np.random.default_rng(3)
    xs = (rng.random(20000) < 1 / 16).astype(int)
    f0, f1 = 15 << 12, 1 << 12  # /2^16
    ans = StreamANS()
    for x in xs:
        ans.push(0 if x == 0 else f0, f1 if x else f0, 16)
    h = 0.3373
    bits = ans.bits - 64  # subtract the seed head
    assert bits / len(xs) == pytest.approx(h, rel=0.05)


def test_streamans_underflow_raises():
    ans = StreamANS()
    ans.push(0, 1, 8)
    ans.pop_advance(0, 1, 8)
    with pytest.raises(ValueError):
        for _ in range(20):
            ans.pop_advance(0, 1, 8)


# ---------------------------------------------------------------------------
# VRans (vectorized lanes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lanes", [1, 3, 64])
def test_vrans_uniform_roundtrip(lanes):
    rng = np.random.default_rng(4)
    rows = 100
    r = 13
    data = rng.integers(0, 1 << r, size=(rows, lanes))
    enc = VRansEncoder(lanes)
    for t in range(rows - 1, -1, -1):
        enc.push_uniform(data[t], r)
    heads, words = enc.finalize()
    dec = VRansDecoder(heads, words)
    for t in range(rows):
        out = dec.pop_uniform(r)
        np.testing.assert_array_equal(out, data[t])
    np.testing.assert_array_equal(dec.heads, np.full(lanes, 1 << 32, np.uint64))


def test_vrans_masked_ragged_roundtrip():
    rng = np.random.default_rng(5)
    lanes, rows, r = 8, 50, 10
    data = rng.integers(0, 1 << r, size=(rows, lanes))
    lens = rng.integers(0, rows + 1, size=lanes)  # per-lane lengths
    mask = np.arange(rows)[:, None] < lens[None, :]
    enc = VRansEncoder(lanes)
    for t in range(rows - 1, -1, -1):
        enc.push_uniform(data[t], r, mask=mask[t])
    heads, words = enc.finalize()
    dec = VRansDecoder(heads, words)
    for t in range(rows):
        out = dec.pop_uniform(r, mask=mask[t])
        np.testing.assert_array_equal(out[mask[t]], data[t][mask[t]])


def test_vrans_pmf_roundtrip():
    rng = np.random.default_rng(6)
    lanes, rows, r = 16, 200, 12
    total = 1 << r
    freqs_tab = np.array([total // 2, total // 4, total // 8, total // 8])
    cums_tab = np.concatenate([[0], np.cumsum(freqs_tab)[:-1]])
    slot2sym = np.repeat(np.arange(4), freqs_tab)
    data = rng.integers(0, 4, size=(rows, lanes))
    enc = VRansEncoder(lanes)
    for t in range(rows - 1, -1, -1):
        enc.push(cums_tab[data[t]], freqs_tab[data[t]], r)
    heads, words = enc.finalize()
    dec = VRansDecoder(heads, words)
    for t in range(rows):
        cf = dec.peek_cf(r)
        sym = slot2sym[cf]
        np.testing.assert_array_equal(sym, data[t])
        dec.advance(cums_tab[sym], freqs_tab[sym], r)


@given(
    st.integers(1, 16),
    st.integers(1, 12),
    st.integers(0, 2**32 - 1),
)
@settings(max_examples=30, deadline=None)
def test_vrans_property_roundtrip(lanes, r, seed):
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(1, 40))
    data = rng.integers(0, 1 << r, size=(rows, lanes))
    enc = VRansEncoder(lanes)
    for t in range(rows - 1, -1, -1):
        enc.push_uniform(data[t], r)
    heads, words = enc.finalize()
    dec = VRansDecoder(heads, words)
    for t in range(rows):
        np.testing.assert_array_equal(dec.pop_uniform(r), data[t])
