"""repro.api — spec grammar, factory, protocol conformance, uniform serving."""

import numpy as np
import pytest

import jax

from repro.api import (FlatIndex, GraphApiIndex, Index, IVFApiIndex,
                       as_api_index, index_factory, parse_spec)
from repro.serve import AnnService, BatchPolicy

jax.config.update("jax_platforms", "cpu")


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    base = rng.standard_normal((1200, 32)).astype(np.float32)
    queries = rng.standard_normal((16, 32)).astype(np.float32)
    return base, queries


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------

CANONICAL_SPECS = [
    "Flat",
    "IVF64,ids=roc",
    "IVF1024,ids=wt1",
    "IVF1024,PQ8x8,ids=roc,codes=polya",
    "IVF256,PQ16x8,ids=gap_ans",
    "NSG16,ids=ef",
    "HNSW32,ids=roc",
    "IVF64,ids=compact,cache_mb=8",
    "IVF64,ids=roc,cache_mb=1.5,engine=xla",
    "NSG8,ids=unc32,cache_mb=4",
]


@pytest.mark.parametrize("spec", CANONICAL_SPECS)
def test_spec_string_round_trips(spec):
    assert str(parse_spec(spec)) == spec


def test_spec_accepts_any_option_order():
    a = parse_spec("IVF64,codes=polya,PQ8x8,engine=xla,ids=roc")
    b = parse_spec("IVF64,PQ8x8,ids=roc,codes=polya,engine=xla")
    assert a == b and str(a) == str(b)


def test_spec_defaults():
    s = parse_spec("IVF128")
    assert s.ids == "roc" and s.pq_m == 0 and s.cache_mb is None
    assert str(s) == "IVF128,ids=roc"
    assert parse_spec("IVF64,PQ4").pq_bits == 8


@pytest.mark.parametrize("bad", [
    "", "IVF", "Flat64", "NSG0", "IVF64,ids=bogus", "IVF64,unknown=1",
    "Flat,PQ8", "Flat,ids=ef", "NSG16,ids=wt", "NSG16,PQ8x8",
    "IVF64,codes=polya",            # codes without PQ
    "IVF64,codes=huffman,PQ8x8",    # unknown code codec
    "IVF64,ids=roc,ids=ef",         # duplicate option
    "IVF64,cache_mb=0", "IVF64,engine=tpu", "Mystery16",
])
def test_spec_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_spec(bad)


def test_factory_spec_property_round_trips():
    for spec in CANONICAL_SPECS:
        assert index_factory(spec).spec == spec


# ---------------------------------------------------------------------------
# protocol conformance + factory build
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,cls", [
    ("Flat", FlatIndex),
    ("IVF16,ids=roc", IVFApiIndex),
    ("NSG8,ids=roc", GraphApiIndex),
    ("HNSW8,ids=ef", GraphApiIndex),
])
def test_factory_builds_protocol_indexes(data, spec, cls):
    base, queries = data
    idx = index_factory(spec)
    assert isinstance(idx, cls)
    assert isinstance(idx, Index)
    idx.build(base)
    dists, ids, st = idx.search(queries, k=5)
    assert ids.shape == (len(queries), 5) and dists.shape == ids.shape
    assert st.wall_s >= 0 and st.ndis > 0
    led = idx.memory_ledger()
    assert led["total_bytes"] > 0 and led["n"] == len(base)


def test_flat_index_is_exact(data):
    base, queries = data
    idx = index_factory("Flat").build(base)
    dists, ids, _ = idx.search(queries, k=3)
    d = (np.sum(queries**2, 1, keepdims=True) - 2 * queries @ base.T
         + np.sum(base**2, 1)[None])
    ref = np.argsort(d, axis=1, kind="stable")[:, :3]
    np.testing.assert_array_equal(ids, ref)


def test_ivf_adapter_matches_inner_index(data):
    base, queries = data
    idx = index_factory("IVF16,ids=roc,engine=xla").build(base, seed=1)
    dists, ids, _ = idx.search(queries, k=5, nprobe=6)
    ids_ref, d_ref, _ = idx.ivf.search_ref(queries, nprobe=6, topk=5)
    np.testing.assert_array_equal(ids, ids_ref)
    np.testing.assert_array_equal(dists, d_ref)


def test_add_extends_every_kind(data):
    base, queries = data
    rng = np.random.default_rng(3)
    extra = rng.standard_normal((40, 32)).astype(np.float32)
    for spec in ["Flat", "IVF16,ids=roc", "IVF16,ids=wt",
                 "IVF16,PQ8x8,ids=ef,codes=polya", "HNSW8,ids=roc"]:
        idx = index_factory(spec).build(base)
        n0 = idx.n if hasattr(idx, "n") else len(base)
        idx.add(extra)
        assert idx.n == n0 + len(extra), spec
        dists, ids, _ = idx.search(queries, k=5)
        assert ids.shape == (len(queries), 5), spec
        if hasattr(idx, "ivf"):  # batched engine still matches the oracle
            ids_b, d_b, _ = idx.ivf.search(queries, nprobe=6, topk=5,
                                           engine="xla")
            ids_r, d_r, _ = idx.ivf.search_ref(queries, nprobe=6, topk=5)
            np.testing.assert_array_equal(ids_b, ids_r)
            np.testing.assert_array_equal(d_b, d_r)


# ---------------------------------------------------------------------------
# factory options: cache budget + engine
# ---------------------------------------------------------------------------

def test_cache_mb_option_sets_budget(data):
    base, _ = data
    idx = index_factory("IVF16,ids=roc,cache_mb=2").build(base)
    assert idx.ivf.decoded_cache.max_bytes == 2 << 20
    gidx = index_factory("NSG8,ids=roc,cache_mb=1").build(base[:300])
    assert gidx.graph.decoded_cache.max_bytes == 1 << 20


def test_service_cache_mb_override(data):
    base, queries = data
    idx = index_factory("IVF16,ids=roc").build(base)
    default = idx.ivf.decoded_cache.max_bytes
    svc = AnnService(idx, topk=5, cache_mb=3, nprobe=6, engine="xla")
    assert idx.ivf.decoded_cache.max_bytes == 3 << 20 != default
    svc.search(queries[:4])
    with pytest.raises(ValueError):
        AnnService(index_factory("Flat").build(base), cache_mb=1)


def test_cache_set_budget_evicts():
    from repro.ann.scan import DecodedListCache

    cache = DecodedListCache(max_bytes=1 << 20)
    for k in range(8):
        cache.get(k, lambda k=k: np.full(64, k, np.int64))
    cache.set_budget(2 * 64 * 8)
    assert cache.bytes <= 2 * 64 * 8
    assert cache.evictions >= 6


# ---------------------------------------------------------------------------
# AnnService: one code path for every index type
# ---------------------------------------------------------------------------

def _serve(index, queries, **opts):
    svc = AnnService(index, topk=5, policy=BatchPolicy(max_batch=8), **opts)
    tickets = [svc.submit(queries[i:i + 3]) for i in range(0, len(queries), 3)]
    svc.flush()
    assert all(t.done for t in tickets)
    st = svc.stats()
    assert st["queries"] == len(queries)
    led = svc.memory_ledger()
    assert led["total_bytes"] > 0
    return np.concatenate([t.ids for t in tickets], axis=0), svc


def test_service_serves_ivf_and_graph_uniformly(data):
    base, queries = data
    ivf = index_factory("IVF16,ids=roc").build(base)
    ids_ivf, svc_ivf = _serve(ivf, queries, nprobe=6, engine="xla")
    ref_ids, _, _ = ivf.ivf.search_ref(queries, nprobe=6, topk=5)
    np.testing.assert_array_equal(ids_ivf, ref_ids)

    graph = index_factory("NSG8,ids=ef").build(base[:400])
    ids_g, svc_g = _serve(graph, queries, ef=24)
    d_ref, ref_g, _ = graph.search(queries, k=5, ef=24)
    np.testing.assert_array_equal(ids_g, ref_g)
    # graph searches feed the same decode counters the IVF path uses
    assert svc_g.stats()["decodes"] > 0


def test_service_wraps_raw_ivf_index(data):
    """Legacy call sites pass a bare IVFIndex; the service auto-adapts it."""
    from repro.ann.ivf import IVFIndex

    base, queries = data
    raw = IVFIndex(nlist=16, id_codec="roc").build(base, seed=1)
    api = as_api_index(raw)
    assert api.ivf is raw and parse_spec(api.spec).nlist == 16
    ids, _ = AnnService(raw, topk=5, nprobe=6, engine="xla"
                        ).search(queries[:6])
    ref, _, _ = raw.search_ref(queries[:6], nprobe=6, topk=5)
    np.testing.assert_array_equal(ids, ref)
