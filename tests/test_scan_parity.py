"""Batched-scan parity: ``IVFIndex.search`` must be bit-identical to the
``search_ref`` oracle — ids AND distances — for every id codec, both
scoring engines, with and without PQ, and across batching edge cases.
Also covers the decode-count invariant and the AnnService micro-batcher.
"""

import numpy as np
import pytest

import jax

from repro.ann.ivf import IVFIndex
from repro.ann.pq import ProductQuantizer
from repro.serve.ann_service import AnnService, BatchPolicy

jax.config.update("jax_platforms", "cpu")

ALL_CODECS = ["unc64", "compact", "ef", "roc", "gap_ans", "wt", "wt1"]
ENGINES = ["xla", "pallas"]


def _data(n=2000, d=32, nq=25, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((n, d)).astype(np.float32)
    queries = rng.standard_normal((nq, d)).astype(np.float32)
    return base, queries


@pytest.fixture(scope="module")
def data():
    return _data()


def _assert_parity(idx, queries, nprobe, topk, engine="xla", **kw):
    ids_r, d_r, st_r = idx.search_ref(queries, nprobe=nprobe, topk=topk)
    ids_b, d_b, st_b = idx.search(queries, nprobe=nprobe, topk=topk,
                                  engine=engine, **kw)
    np.testing.assert_array_equal(ids_b, ids_r)
    np.testing.assert_array_equal(d_b, d_r)       # exact, not allclose
    assert st_b.ndis == st_r.ndis
    return st_b


@pytest.mark.parametrize("codec", ALL_CODECS)
def test_flat_parity_all_codecs(data, codec):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec=codec).build(base, seed=1)
    _assert_parity(idx, queries, nprobe=6, topk=10)


@pytest.mark.parametrize("engine", ENGINES)
def test_flat_parity_engines(data, engine):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    _assert_parity(idx, queries, nprobe=6, topk=10, engine=engine)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("codec", ["roc", "wt"])
def test_pq_parity(data, codec, engine):
    base, queries = data
    pq = ProductQuantizer(m=8, bits=8)
    idx = IVFIndex(nlist=16, id_codec=codec, pq=pq).build(base, seed=1)
    _assert_parity(idx, queries[:12], nprobe=5, topk=8, engine=engine)


@pytest.mark.parametrize("codec", ["roc", "ef", "wt1"])
def test_nprobe_exceeds_nlist(data, codec):
    base, queries = data
    idx = IVFIndex(nlist=8, id_codec=codec).build(base, seed=2)
    _assert_parity(idx, queries[:10], nprobe=50, topk=7)


@pytest.mark.parametrize("codec", ["roc", "gap_ans", "wt"])
def test_clusters_smaller_than_topk(codec):
    base, queries = _data(n=60, d=16, nq=10, seed=3)
    idx = IVFIndex(nlist=16, id_codec=codec).build(base, seed=3)
    # topk > typical cluster size; some queries may find < topk candidates
    _assert_parity(idx, queries, nprobe=2, topk=9)


def test_near_duplicate_tie_boundary():
    """Many near-duplicates collapse to one f32 kernel distance; the
    shortlist must extend through the tie so the exact re-score still
    recovers the oracle's top-k."""
    rng = np.random.default_rng(8)
    v = rng.standard_normal(16).astype(np.float32)
    dupes = v[None] + 1e-7 * rng.standard_normal((40, 16)).astype(np.float32)
    rest = rng.standard_normal((400, 16)).astype(np.float32) + 4.0
    base = np.concatenate([dupes, rest]).astype(np.float32)
    idx = IVFIndex(nlist=4, id_codec="roc").build(base, seed=9)
    _assert_parity(idx, v[None], nprobe=4, topk=10)
    # exact duplicates too (ties in BOTH paths -> stable position order)
    base2 = np.concatenate([np.repeat(v[None], 40, 0), rest]).astype(np.float32)
    idx2 = IVFIndex(nlist=4, id_codec="wt").build(base2, seed=9)
    _assert_parity(idx2, v[None], nprobe=4, topk=10)


def test_query_block_invariance(data):
    """Results are independent of how queries are blocked (batching contract)."""
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    ref = idx.search(queries, nprobe=6, topk=5, query_block=64)
    for qb in (1, 3, 7):
        got = idx.search(queries, nprobe=6, topk=5, query_block=qb)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


def test_decode_count_bounded_by_distinct_probed(data):
    """Cold cache: each distinct probed cluster is decoded at most once per
    call; warm cache: zero decodes."""
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    idx.decoded_cache.clear()
    _, _, st = idx.search(queries, nprobe=6, topk=5)
    assert 0 < st.decodes <= st.distinct_probed
    _, _, st2 = idx.search(queries, nprobe=6, topk=5)
    assert st2.decodes == 0
    assert idx.decoded_cache.stats()["hits"] > 0


def test_decoded_cache_eviction():
    from repro.ann.scan import DecodedListCache

    cache = DecodedListCache(max_bytes=3 * 80)  # room for ~3 10-elem int64
    for k in range(6):
        cache.get(k, lambda k=k: np.full(10, k, np.int64))
    assert cache.bytes <= 3 * 80
    assert cache.evictions > 0
    # most-recent entry survives: decode must NOT be called again
    def boom():
        raise AssertionError("unexpected decode of a cached entry")

    assert cache.get(5, boom)[0] == 5


def test_resolve_ids_empty_input(data):
    base, _ = data
    for codec in ["roc", "ef", "wt"]:
        idx = IVFIndex(nlist=8, id_codec=codec).build(base, seed=4)
        out = idx.resolve_ids(np.zeros(0, np.int64), np.zeros(0, np.int64))
        assert out.shape == (0,) and out.dtype == np.int64


def test_resolve_ids_batch_matches_scalar(data):
    base, _ = data
    for codec in ["roc", "ef", "compact", "wt"]:
        idx = IVFIndex(nlist=16, id_codec=codec).build(base, seed=4)
        rng = np.random.default_rng(5)
        ks = rng.integers(0, 16, size=64)
        offs = np.array([rng.integers(0, max(1, idx.sizes[k])) for k in ks])
        keep = idx.sizes[ks] > 0
        ks, offs = ks[keep], offs[keep]
        got = idx.resolve_ids(ks, offs)
        want = np.array([np.sort(idx._lists[k])[o] for k, o in zip(ks, offs)])
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# device-side top-k select (repro.kernels.seg_topk)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("codec", ALL_CODECS)
def test_device_select_parity_all_codecs(data, codec, engine):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec=codec).build(base, seed=1)
    st = _assert_parity(idx, queries, nprobe=6, topk=10, engine=engine,
                        select="device")
    # every block cut on device; only shortlists crossed to the host
    assert st.device_select == st.batches > 0
    _, _, st_h = idx.search(queries, nprobe=6, topk=10, engine=engine,
                            select="host")
    assert st_h.device_select == 0
    assert 0 < st.host_block_bytes < st_h.host_block_bytes


@pytest.mark.parametrize("engine", ENGINES)
def test_device_select_parity_pq(data, engine):
    base, queries = data
    pq = ProductQuantizer(m=8, bits=8)
    idx = IVFIndex(nlist=16, id_codec="roc", pq=pq).build(base, seed=1)
    st = _assert_parity(idx, queries[:12], nprobe=5, topk=8, engine=engine,
                        select="device")
    assert st.device_select == st.batches > 0


def test_device_select_near_duplicate_ties():
    """The device cut must extend through the same kernel-error band the
    host cut does, so near-duplicate pileups stay bit-identical."""
    rng = np.random.default_rng(8)
    v = rng.standard_normal(16).astype(np.float32)
    dupes = v[None] + 1e-7 * rng.standard_normal((40, 16)).astype(np.float32)
    rest = rng.standard_normal((400, 16)).astype(np.float32) + 4.0
    base = np.concatenate([np.repeat(v[None], 40, 0), dupes, rest])
    idx = IVFIndex(nlist=4, id_codec="roc").build(base.astype(np.float32),
                                                  seed=9)
    _assert_parity(idx, v[None], nprobe=4, topk=10, select="device")


def test_device_select_merge_keys_identical(data):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    _, _, st_h = idx.search(queries, nprobe=6, topk=5, select="host",
                            with_keys=True)
    _, _, st_d = idx.search(queries, nprobe=6, topk=5, select="device",
                            with_keys=True)
    np.testing.assert_array_equal(st_d.merge_keys, st_h.merge_keys)


def test_select_auto_threshold(data):
    """``auto`` takes the device path exactly when the candidate row is at
    least ``select_min`` wide (CPU default: SELECT_MIN_CPU)."""
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    _, _, st_on = idx.search(queries, nprobe=6, topk=5, select="auto",
                             select_min=1)
    assert st_on.device_select == st_on.batches > 0
    _, _, st_off = idx.search(queries, nprobe=6, topk=5, select="auto",
                              select_min=1 << 30)
    assert st_off.device_select == 0


def test_select_unknown_mode_raises(data):
    base, queries = data
    idx = IVFIndex(nlist=8, id_codec="roc").build(base, seed=1)
    with pytest.raises(ValueError, match="select"):
        idx.search(queries[:2], select="gpu")


def test_device_select_query_block_invariance(data):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    ref = idx.search(queries, nprobe=6, topk=5, select="device")
    for qb in (1, 3, 7):
        got = idx.search(queries, nprobe=6, topk=5, select="device",
                         query_block=qb)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


# ---------------------------------------------------------------------------
# AnnService
# ---------------------------------------------------------------------------

def test_service_results_match_oracle(data):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    now = [0.0]
    svc = AnnService(idx, nprobe=6, topk=5, engine="xla",
                     policy=BatchPolicy(max_batch=8, max_wait_s=0.01),
                     clock=lambda: now[0])
    tickets = []
    for i in range(0, len(queries), 3):
        tickets.append(svc.submit(queries[i:i + 3]))
        now[0] += 0.004
    svc.flush()
    assert all(t.done for t in tickets)
    got = np.concatenate([t.ids for t in tickets], axis=0)
    ref_ids, _, _ = idx.search_ref(queries, nprobe=6, topk=5)
    np.testing.assert_array_equal(got, ref_ids)
    st = svc.stats()
    assert st["queries"] == len(queries)
    assert st["batches"] >= 2  # micro-batching actually grouped requests


def test_service_batch_policy(data):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    now = [0.0]
    svc = AnnService(idx, nprobe=6, topk=5, engine="xla",
                     policy=BatchPolicy(max_batch=4, max_wait_s=1.0),
                     clock=lambda: now[0])
    t1 = svc.submit(queries[:2])
    assert not t1.done and svc.pending() == 2      # under both limits
    t2 = svc.submit(queries[2:4])                  # hits max_batch
    assert t1.done and t2.done and t1.batch_size == 4
    t3 = svc.submit(queries[4:5])
    assert not t3.done
    now[0] += 2.0                                  # exceed max_wait
    assert svc.tick() and t3.done
    assert t3.wait_s >= 1.0


def test_service_memory_ledger(data):
    base, queries = data
    idx = IVFIndex(nlist=24, id_codec="roc").build(base, seed=1)
    svc = AnnService(idx, nprobe=6, topk=5, engine="xla")
    svc.search(queries[:8])
    led = svc.memory_ledger()
    assert led["ids_bytes"] < led["ids_bytes_compact"] < led["ids_bytes_unc64"]
    assert led["total_bytes"] > 0
    assert led["decoded_cache_bytes"] >= 0
