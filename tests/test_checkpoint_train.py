"""Fault tolerance: atomic checkpointing, crash-resume, pipeline determinism,
and the end-to-end training driver (loss must go down)."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
from repro.data.pipeline import TokenPipeline
from repro.launch.train import main as train_main


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32)}}


def test_checkpoint_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 7, s, extra={"pipeline": {"seed": 0, "step": 7}})
    assert latest_step(tmp_path) == 7
    restored, manifest = restore_checkpoint(tmp_path, jax.eval_shape(lambda: s))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(s["w"]))
    assert manifest["extra"]["pipeline"]["step"] == 7


def test_checkpoint_survives_partial_write(tmp_path):
    """A half-written step dir must not break resume (crash simulation)."""
    s = _state()
    save_checkpoint(tmp_path, 10, s)
    # simulate a crash mid-write of step 20: tmp dir + stale LATEST pointer
    broken = tmp_path / "step_00000020"
    broken.mkdir()
    (broken / "arrays.npz").write_bytes(b"garbage")
    (tmp_path / "LATEST").write_text("20")
    assert latest_step(tmp_path) == 10  # falls back to newest valid
    restored, m = restore_checkpoint(tmp_path, jax.eval_shape(lambda: s))
    assert m["step"] == 10


def test_pipeline_deterministic_and_resumable():
    p1 = TokenPipeline(vocab=100, batch=4, seq_len=16, seed=3)
    b5 = p1.batch_at(5)
    p2 = TokenPipeline(vocab=100, batch=4, seq_len=16, seed=3).restore(
        {"seed": 3, "step": 5})
    it = iter(p2)
    np.testing.assert_array_equal(next(it)["tokens"], b5["tokens"])
    # shards draw disjoint slices deterministically
    a = TokenPipeline(vocab=100, batch=8, seq_len=16, seed=3, n_shards=2, shard=0)
    b = TokenPipeline(vocab=100, batch=8, seq_len=16, seed=3, n_shards=2, shard=1)
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


def test_train_loss_decreases():
    losses = train_main(["--arch", "gemma3-1b", "--reduced", "--steps", "40",
                         "--batch", "4", "--seq", "64", "--lr", "1e-3"])
    assert losses[-1] < losses[0] - 0.3


def test_train_crash_and_resume(tmp_path):
    """Run 30 steps with ckpt-every 10; 'crash'; resume reproduces the
    uninterrupted run exactly (same final loss)."""
    args = ["--arch", "gemma3-1b", "--reduced", "--batch", "4", "--seq", "32",
            "--ckpt-every", "10", "--ckpt-dir", str(tmp_path)]
    full = train_main(args + ["--steps", "30", "--resume", "never"])
    # crash after 20 steps (fresh dir, same 30-step schedule)
    shutil.rmtree(tmp_path)
    train_main(args + ["--steps", "30", "--resume", "never",
                       "--stop-after", "20"])
    assert latest_step(tmp_path) == 20
    resumed = train_main(args + ["--steps", "30"])  # auto-resume from 20
    assert len(resumed) == 10
    np.testing.assert_allclose(resumed[-1], full[-1], rtol=1e-4)


def test_moe_arch_trains():
    losses = train_main(["--arch", "olmoe-1b-7b", "--reduced", "--steps", "25",
                         "--batch", "4", "--seq", "32", "--lr", "1e-3"])
    assert losses[-1] < losses[0]


def test_hybrid_arch_trains():
    losses = train_main(["--arch", "zamba2-2.7b", "--reduced", "--steps", "25",
                         "--batch", "4", "--seq", "64", "--lr", "1e-3"])
    assert losses[-1] < losses[0]
