"""Distribution-layer tests: sharding rules, gradient compression, pipeline
parallelism, and a real (tiny) multi-device train step."""

import os

import pytest

# 8 virtual devices for this module (set before jax initializes)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.distributed.compression import EFCompressor, compress_tree_int8  # noqa: E402
from repro.distributed.pp import pipeline_apply  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.launch.mesh import make_mesh_compat, use_mesh  # noqa: E402
from repro.models import build  # noqa: E402

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 virtual devices")


def _mesh():
    return make_mesh_compat((2, 4), ("data", "model"))


def test_param_shardings_cover_tree():
    cfg = reduced(get_config("olmoe-1b-7b"))
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = _mesh()
    sh = param_shardings(shapes, mesh, cfg.n_experts)
    n_sharded = 0
    for leaf, s in zip(jax.tree.leaves(shapes), jax.tree.leaves(sh)):
        assert s.mesh.shape == mesh.shape
        for dim, name in zip(leaf.shape, s.spec + (None,) * 10):
            if name:
                size = int(np.prod([mesh.shape[a] for a in
                                    ((name,) if isinstance(name, str) else name)]))
                assert dim % size == 0, (leaf.shape, s.spec)
                n_sharded += 1
    assert n_sharded > 10  # rules actually fire


def test_sharded_train_step_runs():
    """End-to-end jit on a real 2x4 mesh with the repo sharding rules."""
    from repro.train.optim import init_opt
    from repro.train.step import make_train_step

    cfg = reduced(get_config("minitron-4b"))
    mesh = _mesh()
    model, train_step = make_train_step(cfg)
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        shapes = jax.eval_shape(lambda: params)
        p_sh = param_shardings(shapes, mesh, cfg.n_experts)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt = init_opt(params)
        batch = {
            "tokens": jnp.zeros((8, 32), jnp.int32),
            "labels": jnp.zeros((8, 32), jnp.int32),
        }
        b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh)
        batch = jax.tree.map(jax.device_put, batch, b_sh)
        params, opt, metrics = jax.jit(train_step)(params, opt, batch)
        assert bool(jnp.isfinite(metrics["loss"]))


def test_cache_shardings_decode():
    cfg = get_config("granite-20b")  # kv=1: seq must take the model axis
    model = build(cfg)
    mesh = _mesh()
    cache_shapes = jax.eval_shape(lambda: model.init_cache(128, 1024))
    sh = cache_shardings(cache_shapes, mesh, 128, cfg.n_kv_heads)
    kv_leaves = [
        (l, s) for l, s in zip(jax.tree.leaves(cache_shapes), jax.tree.leaves(sh))
        if l.ndim >= 4 and l.shape[-2] == cfg.n_kv_heads
    ]
    assert kv_leaves
    for leaf, s in kv_leaves:
        assert "model" in str(s.spec)  # seq-dim model sharding kicked in


def test_int8_compression_error_bounded():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((128, 64)) * 0.01)}
    q = compress_tree_int8(g)
    err = jnp.abs(q["a"] - g["a"]).max()
    assert float(err) <= 0.01 * 2 / 127 + 1e-6


def test_error_feedback_accumulates():
    """EF residual makes the *sum* of compressed grads track the true sum."""
    rng = np.random.default_rng(1)
    params = {"w": jnp.zeros((32,))}
    comp = EFCompressor(params)
    total_true = np.zeros(32)
    total_comp = np.zeros(32)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(32) * 1e-3)}
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(comp(g)["w"])
    # without EF, bias ~ 50 * quantization step; with EF it stays ~ 1 step
    step = 1e-3 * 3 / 127
    assert np.abs(total_comp - total_true).max() < 5 * step


def test_grad_compression_training_parity():
    """Compressed training must reach a loss close to uncompressed."""
    from repro.launch.train import main as train_main

    base = train_main(["--arch", "gemma3-1b", "--reduced", "--steps", "30",
                       "--batch", "4", "--seq", "32"])
    comp = train_main(["--arch", "gemma3-1b", "--reduced", "--steps", "30",
                       "--batch", "4", "--seq", "32", "--compress-grads"])
    assert comp[-1] < base[0]               # it actually trains
    assert abs(comp[-1] - base[-1]) < 0.25  # and tracks the fp path


def test_pipeline_matches_sequential():
    mesh = make_mesh_compat((4,), ("pod",))
    rng = np.random.default_rng(2)
    n_stages, n_micro, mb, d = 4, 8, 4, 16
    ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jnp.asarray(rng.standard_normal((n_micro, mb, d)))
    piped = pipeline_apply(stage_fn, n_stages, n_micro, mesh, axis="pod")
    with use_mesh(mesh):
        out = piped(ws, x)
    ref = x
    for s in range(n_stages):
        ref = stage_fn(ws[s], ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_sp_decode_matches_dense():
    """Flash-decoding shard_map == dense attention over the gathered cache."""
    from repro.distributed.sp import make_sp_decode

    mesh = make_mesh_compat((4,), ("model",))
    rng = np.random.default_rng(3)
    B, T, H, KV, D = 2, 64, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    valid = jnp.asarray(np.arange(T)[None, :] < 50).repeat(B, 0)

    # dense reference
    G = H // KV
    s = jnp.einsum("bokgd->bkgd", q.reshape(B, 1, KV, G, D))
    scores = jnp.einsum("bkgd,btkd->bkgt", s, k) / jnp.sqrt(D)
    scores = jnp.where(valid[:, None, None, :], scores,
                       jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgt,btkd->bkgd", p, v).reshape(B, 1, H, D)

    with use_mesh(mesh):
        out = make_sp_decode(mesh)(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_elastic_reshard_across_meshes():
    """Checkpoint written under one mesh restores onto a different one."""
    from repro.checkpoint.checkpoint import (restore_checkpoint,
                                             save_checkpoint, reshard)
    from repro.distributed.sharding import param_shardings
    import tempfile

    cfg = reduced(get_config("gemma3-1b"))
    model = build(cfg)
    mesh_a = make_mesh_compat((2, 4), ("data", "model"))
    mesh_b = make_mesh_compat((4, 2), ("data", "model"))
    params = model.init(jax.random.PRNGKey(0))
    shapes = jax.eval_shape(lambda: params)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, params)
        restored, _ = restore_checkpoint(d, shapes)
    sh_b = param_shardings(shapes, mesh_b, cfg.n_experts)
    placed = reshard(restored, sh_b)
    ref = jax.tree.leaves(params)[3]
    new = jax.tree.leaves(placed)[3]
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(new))
