"""Public-surface docstring contract: every ``__all__`` member of the
documented packages (``repro.api``, ``repro.serve``, ``repro.shard``,
``repro.kernels``) carries a non-empty docstring, and every public method
of the protocol-facing classes does too — docs/architecture.md points
readers at these docstrings as the per-symbol reference."""

import importlib
import inspect

import jax
import pytest

jax.config.update("jax_platforms", "cpu")

PACKAGES = ["repro.api", "repro.serve", "repro.shard", "repro.kernels"]


@pytest.mark.parametrize("pkg", PACKAGES)
def test_package_has_docstring_and_all(pkg):
    mod = importlib.import_module(pkg)
    assert mod.__doc__ and mod.__doc__.strip(), f"{pkg} has no docstring"
    assert getattr(mod, "__all__", None), f"{pkg} exports no __all__"


@pytest.mark.parametrize("pkg", PACKAGES)
def test_all_members_documented(pkg):
    mod = importlib.import_module(pkg)
    undocumented = []
    for name in mod.__all__:
        obj = getattr(mod, name)
        doc = inspect.getdoc(obj)
        if not (doc and doc.strip()):
            undocumented.append(name)
    assert not undocumented, (
        f"{pkg}.__all__ members missing docstrings: {undocumented}")


@pytest.mark.parametrize("cls_path", [
    "repro.api:FlatIndex", "repro.api:IVFApiIndex", "repro.api:GraphApiIndex",
    "repro.serve:AnnService", "repro.shard:ShardedAnnService",
])
def test_public_methods_documented(cls_path):
    mod_name, cls_name = cls_path.split(":")
    cls = getattr(importlib.import_module(mod_name), cls_name)
    undocumented = []
    for name, member in inspect.getmembers(cls):
        if name.startswith("_"):
            continue
        if not (inspect.isfunction(member) or isinstance(member, property)):
            continue
        # properties document through fget
        target = member.fget if isinstance(member, property) else member
        if target is None or target.__qualname__.split(".")[0] != cls_name:
            continue  # inherited helpers are documented at their definition
        doc = inspect.getdoc(member)
        if not (doc and doc.strip()):
            undocumented.append(name)
    assert not undocumented, (
        f"{cls_path} public members missing docstrings: {undocumented}")
