"""Tier-1 gate + unit tests for the repro.analysis static-analysis pass.

Three layers:

* fixture tests — every rule fires on its bad fixture (exact lines,
  marked ``# FIRE``) and stays quiet on the good one; stripping the
  ``# repro: ignore[...]`` comments resurfaces exactly the suppressed
  findings.
* framework tests — suppressions, baselines, path normalization, CLI.
* the gate — the full pass over ``src/repro`` must report zero
  non-baselined findings, and stay fast enough to run in tier-1.
"""

import io
import json
import pathlib
import re
import time

import pytest

from repro.analysis import (Finding, all_checkers, analyze_paths,
                            analyze_source, load_baseline, module_path,
                            split_baselined, write_baseline)
from repro.analysis.cli import main

HERE = pathlib.Path(__file__).resolve().parent
FIXTURES = HERE / "analysis_fixtures"
REPO = HERE.parent
SRC_REPRO = REPO / "src" / "repro"
BASELINE = REPO / "analysis_baseline.json"

#: rule -> (bad fixture, good fixture, virtual module path)
CASES = {
    "RPA001": ("rpa001_bad.py", "rpa001_good.py",
               "repro/core/codecs_fixture.py"),
    "RPA002": ("rpa002_bad.py", "rpa002_good.py",
               "repro/shard/service_fixture.py"),
    "RPA003": ("rpa003_bad.py", "rpa003_good.py",
               "repro/core/container.py"),
    "RPA004": ("rpa004_bad.py", "rpa004_good.py",
               "repro/ann/pack_fixture.py"),
    "RPA005": ("rpa005_bad.py", "rpa005_good.py",
               "repro/kernels/fixture.py"),
    "RPA006": ("rpa006_bad.py", "rpa006_good.py",
               "repro/shard/router_fixture.py"),
}


def _read(name):
    return (FIXTURES / name).read_text()


def _fire_lines(source):
    return [i for i, line in enumerate(source.splitlines(), 1)
            if "# FIRE" in line]


# ---------------------------------------------------------------------------
# per-rule fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_fires_on_bad_fixture(rule):
    bad, _, vpath = CASES[rule]
    source = _read(bad)
    findings = analyze_source(source, vpath, rules=[rule])
    assert [f.line for f in findings] == _fire_lines(source)
    assert {f.rule for f in findings} == {rule}
    assert all(f.path == vpath for f in findings)


@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_quiet_on_good_fixture(rule):
    _, good, vpath = CASES[rule]
    findings = analyze_source(_read(good), vpath, rules=[rule])
    assert findings == []


@pytest.mark.parametrize("rule", sorted(CASES))
def test_suppression_comment_suppresses(rule):
    """Stripping `# repro: ignore[...]` resurfaces exactly those lines."""
    bad, _, vpath = CASES[rule]
    source = _read(bad)
    suppressed_lines = [i for i, line in enumerate(source.splitlines(), 1)
                        if "repro: ignore" in line]
    assert suppressed_lines, f"{bad} must exercise suppression"
    stripped = re.sub(r"#\s*repro:\s*ignore\[[^\]]*\]", "", source)
    before = {f.line for f in analyze_source(source, vpath, rules=[rule])}
    after = {f.line for f in analyze_source(stripped, vpath, rules=[rule])}
    assert after - before == set(suppressed_lines)


def test_bare_ignore_suppresses_every_rule():
    src = "def route(ix):\n    return hasattr(ix, 'ivf')  # repro: ignore\n"
    assert analyze_source(src, "repro/api/fixture.py", rules=["RPA001"]) == []


def test_rpa006_allowlisted_path_must_record():
    source = _read("rpa006_allowlisted.py")
    findings = analyze_source(source, "repro/launch/dryrun.py",
                              rules=["RPA006"])
    assert [f.line for f in findings] == _fire_lines(source)
    # same code outside the allowlist: every broad except fires
    outside = analyze_source(source, "repro/launch/other.py",
                             rules=["RPA006"])
    assert len(outside) == 2


# ---------------------------------------------------------------------------
# rule scoping
# ---------------------------------------------------------------------------

def test_rpa003_scopes_to_writer_functions():
    src = ("import uuid\n"
           "def pack_header(m):\n    return uuid.uuid4()\n"
           "def unrelated(m):\n    return uuid.uuid4()\n")
    findings = analyze_source(src, "repro/ann/other.py", rules=["RPA003"])
    assert [f.line for f in findings] == [3]   # only inside pack_header


def test_rpa005_only_applies_under_kernels_and_scan():
    src = "import jax\n@jax.jit\ndef f(x):\n    return float(x[0])\n"
    hot = analyze_source(src, "repro/kernels/x.py", rules=["RPA005"])
    cold = analyze_source(src, "repro/serve/x.py", rules=["RPA005"])
    assert [f.line for f in hot] == [4]
    assert cold == []


def test_rpa001_hasattr_only_on_hot_paths():
    src = "def f(ix):\n    return hasattr(ix, 'ivf')\n"
    hot = analyze_source(src, "repro/serve/x.py", rules=["RPA001"])
    cold = analyze_source(src, "repro/launch/x.py", rules=["RPA001"])
    assert [f.line for f in hot] == [2]
    assert cold == []


# ---------------------------------------------------------------------------
# RPA007 — spec-grammar / docs drift (tmp-tree fixtures: the rule reads
# docs/architecture.md relative to the analyzed file)
# ---------------------------------------------------------------------------

_GRAMMAR_DOC = """# Architecture

```text spec-grammar
spec := struct ("," key "=" value)*

{keys}
```
"""


def _spec_tree(tmp_path, code_keys, doc_keys=None, doc=True,
               keys_line="KNOWN_OPTION_KEYS = ({keys},)"):
    """tmp/src/repro/api/spec.py + tmp/docs/architecture.md; returns the
    spec path to analyze."""
    spec = tmp_path / "src" / "repro" / "api" / "spec.py"
    spec.parent.mkdir(parents=True)
    keys = ", ".join(repr(k) for k in code_keys)
    spec.write_text('"""fixture."""\n' + keys_line.format(keys=keys) + "\n")
    if doc:
        doc_path = tmp_path / "docs" / "architecture.md"
        doc_path.parent.mkdir()
        lines = "\n".join(f"{k} = <value>" for k in (doc_keys or []))
        doc_path.write_text(_GRAMMAR_DOC.format(keys=lines))
    return str(spec)


def _rpa007(path):
    from repro.analysis import analyze_file

    return analyze_file(path, rules=["RPA007"])


def test_rpa007_in_sync_is_quiet(tmp_path):
    keys = ["ids", "engine"]
    assert _rpa007(_spec_tree(tmp_path, keys, keys)) == []


def test_rpa007_parsed_but_undocumented(tmp_path):
    f = _rpa007(_spec_tree(tmp_path, ["ids", "engine"], ["ids"]))
    assert len(f) == 1 and "'engine'" in f[0].message
    assert "missing from the spec-grammar" in f[0].message


def test_rpa007_documented_but_not_parsed(tmp_path):
    f = _rpa007(_spec_tree(tmp_path, ["ids"], ["ids", "bogus"]))
    assert len(f) == 1 and "'bogus'" in f[0].message
    assert "not parsed" in f[0].message


def test_rpa007_missing_grammar_block(tmp_path):
    spec = _spec_tree(tmp_path, ["ids"], doc=False)
    doc = tmp_path / "docs" / "architecture.md"
    doc.parent.mkdir()
    doc.write_text("# Architecture\n\nno fenced grammar here\n")
    f = _rpa007(spec)
    assert len(f) == 1 and "spec-grammar fenced block" in f[0].message


def test_rpa007_missing_doc_file(tmp_path):
    f = _rpa007(_spec_tree(tmp_path, ["ids"], doc=False))
    assert len(f) == 1 and "cannot locate" in f[0].message


def test_rpa007_keys_must_be_literal_tuple(tmp_path):
    spec = _spec_tree(tmp_path, ["ids"], ["ids"],
                      keys_line="KNOWN_OPTION_KEYS = tuple({keys},)")
    f = _rpa007(spec)
    assert len(f) == 1 and "module-level tuple" in f[0].message


def test_rpa007_scoped_to_spec_module(tmp_path):
    other = tmp_path / "src" / "repro" / "api" / "other.py"
    other.parent.mkdir(parents=True)
    other.write_text("KNOWN_OPTION_KEYS = ('ids',)\n")
    assert _rpa007(str(other)) == []


def test_rpa007_real_repo_in_sync():
    # the committed grammar block in docs/architecture.md matches what
    # parse_spec accepts — the live version of the drift the rule guards
    from repro.analysis import analyze_file
    from repro.api.spec import KNOWN_OPTION_KEYS, parse_spec

    spec_py = SRC_REPRO / "api" / "spec.py"
    assert analyze_file(str(spec_py), rules=["RPA007"]) == []
    # KNOWN_OPTION_KEYS is itself in sync with the parser
    for key in KNOWN_OPTION_KEYS:
        with pytest.raises(ValueError) as e:
            parse_spec(f"IVF8,{key}=@@bad@@")
        assert "unknown spec option" not in str(e.value)
    with pytest.raises(ValueError, match="unknown spec option"):
        parse_spec("IVF8,nope=1")


# ---------------------------------------------------------------------------
# framework
# ---------------------------------------------------------------------------

def test_finding_str_and_fingerprint():
    f = Finding(path="repro/a.py", line=3, rule="RPA001", message="m")
    assert str(f) == "repro/a.py:3: RPA001: m"
    assert f.fingerprint == "repro/a.py::RPA001::m"
    assert f.to_dict()["line"] == 3


def test_module_path_normalization():
    assert module_path("/x/y/src/repro/ann/scan.py") == "repro/ann/scan.py"
    assert module_path("repro/core/codecs.py") == "repro/core/codecs.py"
    assert module_path("./tests/foo.py") == "tests/foo.py"


def test_syntax_error_becomes_rpa000():
    findings = analyze_source("def broken(:\n", "repro/x.py")
    assert len(findings) == 1
    assert findings[0].rule == "RPA000"


def test_unknown_rule_rejected():
    with pytest.raises(ValueError, match="RPA999"):
        analyze_source("x = 1\n", "repro/x.py", rules=["RPA999"])


def test_registry_has_all_rules():
    rules = {c.rule for c in all_checkers()}
    # RPA007 checks against a docs artifact, so its fixtures are tmp
    # trees (below) rather than CASES entries
    assert rules == set(CASES) | {"RPA007"}


def test_baseline_round_trip(tmp_path):
    f1 = Finding(path="repro/a.py", line=3, rule="RPA001", message="m1")
    f2 = Finding(path="repro/b.py", line=9, rule="RPA006", message="m2")
    path = tmp_path / "base.json"
    write_baseline(str(path), [f1, f2, f1])          # dedup on write
    base = load_baseline(str(path))
    assert base == {f1.fingerprint, f2.fingerprint}
    # fingerprints are line-independent: a drifted copy still matches
    drifted = Finding(path="repro/a.py", line=99, rule="RPA001",
                      message="m1")
    new, old = split_baselined([drifted, f2], base)
    assert new == [] and len(old) == 2
    assert load_baseline(str(tmp_path / "missing.json")) == frozenset()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_flags_bad_fixture_and_json(tmp_path):
    bad = FIXTURES / "rpa004_bad.py"
    out = io.StringIO()
    rc = main([str(bad), "--rules", "RPA004", "--format", "json",
               "--baseline", str(tmp_path / "none.json")], out=out)
    assert rc == 1
    payload = json.loads(out.getvalue())
    assert {e["rule"] for e in payload["findings"]} == {"RPA004"}
    assert payload["baselined"] == []


def test_cli_baseline_workflow(tmp_path):
    bad = FIXTURES / "rpa004_bad.py"
    base = tmp_path / "base.json"
    out = io.StringIO()
    rc = main([str(bad), "--rules", "RPA004", "--write-baseline",
               "--baseline", str(base)], out=out)
    assert rc == 0 and base.exists()
    rc = main([str(bad), "--rules", "RPA004", "--baseline", str(base)],
              out=out)
    assert rc == 0          # everything grandfathered
    rc = main([str(bad), "--rules", "RPA004",
               "--baseline", str(tmp_path / "empty.json")], out=out)
    assert rc == 1


def test_cli_list_rules():
    out = io.StringIO()
    assert main(["--list-rules"], out=out) == 0
    assert "RPA001" in out.getvalue()


# ---------------------------------------------------------------------------
# the tier-1 gate: full pass over src/repro
# ---------------------------------------------------------------------------

def test_full_repo_pass_is_clean_and_fast():
    t0 = time.perf_counter()
    findings = analyze_paths([str(SRC_REPRO)])
    elapsed = time.perf_counter() - t0
    baseline = load_baseline(str(BASELINE) if BASELINE.exists() else None)
    new, _ = split_baselined(findings, baseline)
    assert new == [], "new static-analysis findings:\n" + "\n".join(
        str(f) for f in new)
    # lint must stay cheap enough to live in tier-1 (ISSUE 9: ~5s budget)
    assert elapsed < 5.0, f"full-repo analysis took {elapsed:.2f}s"


def test_committed_baseline_is_minimal():
    # the committed baseline grandfathers nothing: findings got fixed,
    # not buried (ISSUE 9 acceptance criterion)
    assert BASELINE.exists()
    data = json.loads(BASELINE.read_text())
    assert data["findings"] == []
