"""Epoched id/code storage — online ingest parity and accounting.

The acceptance contract for the epoch scheme (repro.core.epoch): after
ANY sequence of add / compact / save / load, search results must be
bit-identical — ids AND distances — to a from-scratch rebuild over the
same rows, for every id codec and both engines.  Plus the satellites:
(epoch, cluster) cache keying, the 2Q cache policy, merge-key overflow
guards, RIDX v3 round-trips with id_bits accounting, and sharded
routed ingest.
"""

import numpy as np
import pytest

from repro.ann.ivf import IVFIndex
from repro.ann.scan import (DecodedListCache, MERGE_KEY_OFFSET_BITS,
                            MERGE_KEY_RANK_BITS, pack_merge_keys)
from repro.api import index_factory, load_index, parse_spec, save_index
from repro.core.epoch import EpochStore
from repro.shard import plan_shards
from repro.shard.service import ShardedAnnService

ID_CODECS = ["unc64", "unc32", "compact", "ef", "roc", "gap_ans", "wt", "wt1"]
D = 20


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    return (rng.normal(size=(400, D)).astype(np.float32),
            rng.normal(size=(90, D)).astype(np.float32),
            rng.normal(size=(10, D)).astype(np.float32))


def _rebuilt(spec, x_all, centroids, seed=0):
    """From-scratch oracle over the full row set (shared quantizer)."""
    idx = index_factory(spec)
    if hasattr(idx, "ivf"):
        return idx.build(x_all, seed=seed, centroids=centroids)
    return idx.build(x_all, seed=seed)


# ---------------------------------------------------------------------------
# IVF add/search parity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ids", ID_CODECS)
def test_ivf_add_parity_all_codecs(data, ids):
    x, extra, q = data
    spec = f"IVF10,ids={ids}"
    idx = index_factory(spec).build(x, seed=0)
    idx.add(extra[:40])
    idx.add(extra[40:41])          # single-row epoch
    idx.add(extra[41:0:-1][:0])    # empty add is a no-op
    idx.add(extra[41:])
    assert idx.ivf.n_epochs == 4
    ref = _rebuilt(spec, np.concatenate([x, extra]), idx.ivf.centroids)
    d1, i1, _ = idx.search(q, k=10)
    d2, i2, _ = ref.search(q, k=10)
    assert np.array_equal(i1, i2)
    assert np.array_equal(d1, d2)
    # reference engine agrees too
    ir, dr, _ = idx.ivf.search_ref(q, topk=10)
    assert np.array_equal(i1, ir) and np.array_equal(d1, dr)
    # compaction changes bytes, never results
    idx.ivf.compact()
    assert idx.ivf.n_epochs == 1
    d3, i3, _ = idx.search(q, k=10)
    assert np.array_equal(i1, i3) and np.array_equal(d1, d3)
    assert idx.ivf.id_bits() == ref.ivf.id_bits()


def test_ivf_pq_polya_add_parity(data):
    x, extra, q = data
    spec = "IVF10,PQ4x8,ids=roc,codes=polya"
    idx = index_factory(spec).build(x, seed=0)
    idx.add(extra[:50])
    idx.add(extra[50:])
    ref = index_factory(spec)
    ref.ivf.pq = idx.ivf.pq        # shared codebooks: the same quantization
    ref.build(np.concatenate([x, extra]), seed=0, centroids=idx.ivf.centroids)
    d1, i1, _ = idx.search(q, k=10)
    d2, i2, _ = ref.search(q, k=10)
    assert np.array_equal(i1, i2) and np.array_equal(d1, d2)
    # per-epoch Pólya streams cover every stored code
    assert sum(int(sum(b["sizes"])) for b in idx.ivf._code_blobs) == idx.ivf.n
    idx.ivf.compact()
    d3, i3, _ = idx.search(q, k=10)
    assert np.array_equal(i1, i3) and np.array_equal(d1, d3)
    assert idx.ivf.code_bits_per_element() == ref.ivf.code_bits_per_element()


def test_ivf_max_epochs_autocompact(data):
    x, extra, _ = data
    idx = index_factory("IVF10,ids=roc,max_epochs=2").build(x, seed=0)
    for lo in range(0, 80, 10):
        idx.add(extra[lo:lo + 10])
        assert idx.ivf.n_epochs <= 2
    assert idx.n == x.shape[0] + 80


# ---------------------------------------------------------------------------
# graph indexes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["NSG8,ids=roc", "HNSW8,ids=ef",
                                  "HNSW8,ids=gap_ans"])
def test_graph_add_engines_agree(data, spec):
    x, extra, q = data
    idx = index_factory(spec).build(x[:200], seed=0)
    idx.add(extra[:15])
    idx.add(extra[15:30])
    assert idx.graph.n_epochs > 1
    i1, d1, _ = idx.graph.search(q, ef=64, topk=10)
    i2, d2, _ = idx.graph.search_ref(q, ef=64, topk=10)
    assert np.array_equal(i1, i2) and np.array_equal(d1, d2)
    idx.graph.compact()
    assert idx.graph.n_epochs == 1
    i3, d3, _ = idx.graph.search(q, ef=64, topk=10)
    assert np.array_equal(i1, i3) and np.array_equal(d1, d3)


def test_graph_max_epochs_autocompact(data):
    x, extra, _ = data
    idx = index_factory("HNSW8,ids=roc,max_epochs=2").build(x[:150], seed=0)
    for lo in range(0, 30, 10):
        idx.add(extra[lo:lo + 10])
        assert idx.graph.n_epochs <= 2


# ---------------------------------------------------------------------------
# RIDX v3 round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["IVF10,ids=roc", "IVF10,ids=wt1",
                                  "IVF10,PQ4x8,ids=ef,codes=polya",
                                  "HNSW8,ids=roc"])
def test_ridx_v3_roundtrip_mid_ingest(data, tmp_path, spec):
    x, extra, q = data
    idx = index_factory(spec).build(x, seed=0)
    idx.add(extra[:30])
    idx.add(extra[30:60])
    path = tmp_path / "i.ridx"
    save_index(idx, path)
    idx2 = load_index(path)
    inner = getattr(idx, "ivf", None) or idx.graph
    inner2 = getattr(idx2, "ivf", None) or idx2.graph
    assert inner2.n_epochs == inner.n_epochs
    assert inner2.id_bits() == inner.id_bits()      # bpv accounting round-trips
    d1, i1, _ = idx.search(q, k=10)
    d2, i2, _ = idx2.search(q, k=10)
    assert np.array_equal(i1, i2) and np.array_equal(d1, d2)
    # add-after-load continues the epoch sequence losslessly
    idx.add(extra[60:])
    idx2.add(extra[60:])
    d3, i3, _ = idx.search(q, k=10)
    d4, i4, _ = idx2.search(q, k=10)
    assert np.array_equal(i3, i4) and np.array_equal(d3, d4)


def test_spec_roundtrip_ingest_keys():
    s = "IVF32,ids=roc,cache_policy=2q,max_epochs=4"
    assert str(parse_spec(s)) == s
    assert parse_spec(s).max_epochs == 4
    with pytest.raises(ValueError):
        parse_spec("Flat,cache_policy=2q")
    with pytest.raises(ValueError):
        parse_spec("Flat,max_epochs=3")
    with pytest.raises(ValueError):
        parse_spec("IVF32,cache_policy=mru")
    with pytest.raises(ValueError):
        parse_spec("IVF32,max_epochs=0")


def test_memory_ledger_reports_epochs(data):
    x, extra, _ = data
    idx = index_factory("IVF10,ids=roc").build(x, seed=0)
    idx.add(extra[:30])
    led = idx.memory_ledger()
    assert led["epochs"] == 2.0
    idx.ivf.compact()
    assert idx.memory_ledger()["epochs"] == 1.0


# ---------------------------------------------------------------------------
# epoch-aware caching
# ---------------------------------------------------------------------------

def test_add_preserves_warm_cache_entries(data):
    """Appending never invalidates warm (epoch, cluster) entries; only
    compaction (which renumbers epochs) clears the cache."""
    x, extra, q = data
    idx = index_factory("IVF10,ids=roc").build(x, seed=0)
    idx.search(q, k=10)
    cache = idx.ivf.decoded_cache
    warm = len(cache)
    assert warm > 0
    idx.add(extra[:30])
    assert len(cache) >= warm               # nothing evicted by the add
    d0 = cache.decodes
    idx.search(q, k=10)
    # old epochs hit the warm entries; only epoch-1 lists decode fresh
    assert cache.decodes - d0 <= idx.ivf.nlist
    idx.ivf.compact()
    assert len(cache) == 0


def test_cache_2q_scan_resistance():
    row = np.arange(10, dtype=np.int64)
    cache = DecodedListCache(max_bytes=4 * row.nbytes, policy="2q")
    # touch A twice -> protected
    cache.get("A", lambda: row.copy())
    cache.get("A", lambda: row.copy())
    assert cache.stats()["promotions"] == 1
    # a burst of one-shot keys must not evict the protected entry
    for i in range(20):
        cache.get(("scan", i), lambda: row.copy())
    d0 = cache.decodes
    cache.get("A", lambda: row.copy())
    assert cache.decodes == d0              # A survived the scan
    st = cache.stats()
    assert st["protected_entries"] >= 1
    assert st["bytes"] <= 4 * row.nbytes


def test_cache_lru_stats_shape_unchanged():
    cache = DecodedListCache(max_bytes=1 << 10)
    cache.get("k", lambda: np.zeros(4, np.int64))
    assert set(cache.stats()) == {"entries", "bytes", "hits", "decodes",
                                  "evictions"}


def test_cache_policy_via_factory(data):
    x, _, q = data
    idx = index_factory("IVF10,ids=roc,cache_policy=2q").build(x, seed=0)
    assert idx.ivf.decoded_cache.policy == "2q"
    idx.search(q, k=10)
    idx.search(q, k=10)
    assert idx.ivf.decoded_cache.stats()["promotions"] > 0


def test_cache_survives_pickle_roundtrip(data):
    import pickle

    x, _, q = data
    idx = index_factory("IVF10,ids=roc,cache_policy=2q").build(x, seed=0)
    idx.search(q, k=10)
    ivf2 = pickle.loads(pickle.dumps(idx.ivf))
    assert ivf2.decoded_cache.policy == "2q"      # __setstate__ re-attaches
    assert len(ivf2.decoded_cache) == 0
    i, d, _ = ivf2.search(q, topk=10)
    d0, i0, _ = idx.search(q, k=10)
    assert np.array_equal(i, i0) and np.array_equal(d, d0)


# ---------------------------------------------------------------------------
# merge-key packing guards
# ---------------------------------------------------------------------------

def test_pack_merge_keys_boundaries():
    offs = np.array([0, (1 << MERGE_KEY_OFFSET_BITS) - 1], np.int64)
    ranks = np.array([(1 << MERGE_KEY_RANK_BITS) - 1, 0], np.int64)
    keys = pack_merge_keys(ranks, offs)
    assert keys.dtype == np.uint64
    assert int(keys[1]) == (1 << MERGE_KEY_OFFSET_BITS) - 1
    with pytest.raises(OverflowError):
        pack_merge_keys(np.array([0]), np.array([1 << MERGE_KEY_OFFSET_BITS]))
    with pytest.raises(OverflowError):
        pack_merge_keys(np.array([1 << MERGE_KEY_RANK_BITS]), np.array([0]))


# ---------------------------------------------------------------------------
# epoch store unit behavior
# ---------------------------------------------------------------------------

def test_epoch_store_rejects_gaps():
    store = EpochStore(2, "roc")
    store.append([np.array([0, 2]), np.array([1])], 0, 3)
    with pytest.raises(ValueError):
        store.append([np.zeros(0, np.int64)] * 2, 5, 2)   # hole in id space
    with pytest.raises(ValueError):
        store.append([np.zeros(0, np.int64)] * 2, 3, 0)   # empty universe


def test_epoch_store_resolve_across_epochs():
    store = EpochStore(2, "roc")
    store.append([np.array([0, 2]), np.array([1])], 0, 3)     # ids 0..2
    store.append([np.array([1]), np.array([0, 2])], 3, 3)     # ids 3..5
    cache = DecodedListCache()
    # cluster 0 holds [0, 2, 4]; cluster 1 holds [1, 3, 5]
    got = store.resolve(np.array([0, 0, 0, 1, 1, 1]),
                        np.array([0, 1, 2, 0, 1, 2]), cache)
    assert got.tolist() == [0, 2, 4, 1, 3, 5]


# ---------------------------------------------------------------------------
# sharded routed ingest
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["IVF8,ids=roc", "IVF8,ids=wt",
                                  "IVF8,PQ4x8,ids=roc,codes=polya"])
def test_sharded_ivf_ingest_bit_parity(data, spec):
    x, extra, q = data
    mono = index_factory(spec).build(x, seed=0)
    mono.add(extra[:20])                       # epochs exist before the split
    plan = plan_shards(mono, 3, by="range")
    with ShardedAnnService(plan, topk=10) as svc:
        mono.add(extra[20:50])
        mono.add(extra[50:])
        svc.add(extra[20:50])
        svc.add(extra[50:])
        ids_s, d_s = svc.search(q)
        d_m, ids_m, _ = mono.search(q, k=10)
        assert np.array_equal(ids_s, ids_m)
        assert np.array_equal(d_s, d_m)
        # every shard sealed every epoch with the global universe
        for w in svc._workers:
            assert w.index.ivf.n == mono.ivf.n
            assert w.index.ivf.n_epochs == mono.ivf.n_epochs
        assert svc.stats()["add_rows"] == 70


def test_sharded_hash_ingest_routes_all_rows(data):
    x, extra, q = data
    mono = index_factory("Flat").build(x, seed=0)
    plan = plan_shards(mono, 3, by="hash")
    ref = index_factory("Flat").build(np.concatenate([x, extra]))
    with ShardedAnnService(plan, topk=10) as svc:
        t = svc.add(extra)
        assert t.done and t.ids[0] == x.shape[0]
        assert sum(int(w.index.n) for w in svc._workers) == ref.n
        ids_s, d_s = svc.search(q)
        d_m, ids_m, _ = ref.search(q, k=10)
        assert np.array_equal(ids_s, ids_m)
        assert np.array_equal(d_s, d_m)


def test_sharded_ingest_needs_plan(data):
    x, _, _ = data
    mono = index_factory("IVF8,ids=roc").build(x, seed=0)
    shards = plan_shards(mono, 2, by="range").indexes
    with ShardedAnnService(shards, topk=5) as svc:   # plan-less construction
        with pytest.raises(ValueError):
            svc.submit_add(x[:3])


def test_planner_shard_add_still_guarded(data):
    x, _, _ = data
    mono = index_factory("Flat").build(x, seed=0)
    plan = plan_shards(mono, 2, by="hash")
    with pytest.raises(ValueError):
        plan.indexes[0].add(x[:2])           # direct add bypasses routing


def test_service_microbatched_ingest(data):
    x, extra, q = data
    idx = index_factory("IVF10,ids=roc").build(x, seed=0)
    from repro.serve.ann_service import AnnService, BatchPolicy

    svc = AnnService(idx, topk=10,
                     policy=BatchPolicy(max_batch=1 << 30,
                                        max_wait_s=float("inf")))
    t1 = svc.submit_add(extra[:10])
    t2 = svc.submit_add(extra[10:30])
    assert not t1.done and svc.pending_adds() == 30
    svc.flush_adds()
    assert t1.done and t2.done
    assert t1.ids[0] == x.shape[0] and t2.ids[-1] == x.shape[0] + 29
    assert idx.ivf.n_epochs == 2             # one epoch per flush, not per add
    # read-your-writes: a query flush applies pending adds first
    svc.submit_add(extra[30:40])
    ids, _ = svc.search(q)
    assert idx.n == x.shape[0] + 40
    assert svc.stats()["add_batches"] == 2
