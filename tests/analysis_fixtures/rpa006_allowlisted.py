# analyzed under the allowlisted path repro/launch/dryrun.py: broad
# excepts are the harvesting contract there, but must record the failure
def harvest(jobs):
    records = []
    for job in jobs:
        try:
            records.append({"status": "ok", "out": job()})
        except Exception as e:  # records the failure: fine
            records.append(
                {"status": "error", "error": f"{type(e).__name__}: {e}"})
    return records


def swallow(job):
    try:
        return job()
    except Exception:  # FIRE (allowlisted but swallows silently)
        return None
