# analyzed by tests under the virtual path repro/core/codecs_fixture.py
# (never imported; parsed only).  Marked lines must each emit exactly
# one RPA001 finding.
from repro.core.codecs import IdCodec


class MissingSurface(IdCodec):  # FIRE (size_bits not statically defined)
    def encode(self, ids, universe):
        return b""

    def decode(self, blob):  # FIRE (signature drops universe)
        return []


class WrongGather(IdCodec):
    def encode(self, ids, universe):
        return b""

    def decode(self, blob, universe):
        return []

    def size_bits(self, blob):
        return 0

    def gather(self, blob, positions):  # FIRE (contract names it offsets)
        return None


class NotACodec:  # unrelated class: no codec findings
    def decode(self, whatever):
        return whatever


def route(index):
    if hasattr(index, "ivf"):  # FIRE (duck-typing on the hot path)
        return "ivf"
    if hasattr(index, "spec"):  # repro: ignore[RPA001]
        return "api"
    return "raw"
