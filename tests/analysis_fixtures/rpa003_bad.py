# determinism violations; analyzed under repro/core/container.py
import os
import time
import uuid

import numpy as np


def pack_blobs(parts, root):
    for p in set(parts):  # FIRE (unsorted set iteration)
        _consume(p)
    for k, v in parts.items():  # FIRE (dict-view iteration, order implicit)
        _consume(k, v)
    for k, v in sorted(parts.items()):  # explicit order: fine
        _consume(k, v)
    blob_id = uuid.uuid4()  # FIRE (nondeterministic id in the byte stream)
    names = os.listdir(root)  # FIRE (OS-ordered directory listing)
    names2 = sorted(os.listdir(root))  # wrapped: fine
    jitter = np.random.rand()  # FIRE (random source)
    stamp = time.time()  # repro: ignore[RPA003]
    return blob_id, names, names2, jitter, stamp


def _consume(*a):
    return a
