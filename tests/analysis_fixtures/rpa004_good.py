# guarded / harmless shifts: zero RPA004 findings
import numpy as np

OFFSET_BITS = 40
TABLE_SIZE = 1 << 40            # literal left operand: python int, no wrap


def pack_guarded(rank, offset):
    if offset >= (1 << OFFSET_BITS):
        raise OverflowError("offset exceeds the packed width")
    return (rank << OFFSET_BITS) | int(offset)


def pack_cast(rank, offset):
    r = np.asarray(rank, np.uint64)
    return (r << np.uint64(OFFSET_BITS)) | np.uint64(offset)


def narrow(a):
    return a << 8               # < 32 bits: out of scope
