# deterministic writer: zero RPA003 findings under repro/core/container.py
import json


def pack_sections(sections):
    blob = bytearray()
    for name in sorted(sections):          # explicit ordering
        blob += sections[name]
    for entry in [1, 2, 3]:                # list iteration: deterministic
        blob.append(entry)
    manifest = json.dumps(
        {"sections": sorted(sections)}, sort_keys=True)
    return bytes(blob), manifest
