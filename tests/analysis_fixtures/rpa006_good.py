# narrow excepts: zero RPA006 findings under repro/shard/router_fixture.py
def risky(work, stats):
    try:
        work()
    except (TimeoutError, ValueError) as e:
        stats.partial = True
        stats.errors.append(repr(e))
    try:
        work()
    except KeyError:
        return None
