# pure traced code + free host helpers: zero RPA005 findings under
# repro/kernels/fixture.py
import functools

import jax
import jax.numpy as jnp
import numpy as np

import jax.experimental.pallas as pl


@jax.jit
def scorer(x):
    d = jnp.sum(x * x, axis=-1)
    return jnp.sqrt(d).astype(jnp.float32)


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def run(x):
    return pl.pallas_call(_kern, out_shape=None)(x)


def host_helper(x):
    # not traced: host-side numpy / coercions are fine here
    arr = np.asarray(x)
    total = float(arr.sum())
    print("host total", total)
    return int(total)


@functools.partial(jax.jit, static_argnames=("block",))
def blocked(x, block):
    return jnp.reshape(x, (-1, block))
