# disciplined locking: zero RPA002 findings expected
from concurrent.futures import ThreadPoolExecutor
from threading import Lock


class Router:
    def __init__(self):
        self._pool = ThreadPoolExecutor(2)
        self._locks = [Lock(), Lock()]
        self._workers = []
        self.count = 0

    def kick(self, s, batch):
        return self._pool.submit(self._work, s, batch)

    def _work(self, s, batch):
        with self._locks[s]:
            self.count += 1
            svc = self._workers[s]
            return svc.flush()

    def reset(self):
        with self._locks[0]:
            self.count = 0
        self.caller_only = 1  # never touched on the executor: fine
