# lock-discipline violations; analyzed under repro/shard/service_fixture.py
from concurrent.futures import ThreadPoolExecutor
from threading import Lock


class Router:
    def __init__(self):
        self._pool = ThreadPoolExecutor(2)
        self._lock = Lock()
        self._workers = []
        self.count = 0
        self.log = []

    def kick(self, s, batch):
        return self._pool.submit(self._work, s, batch)

    def _work(self, s, batch):
        self.count += 1  # FIRE (executor write outside the lock)
        self.log.append(s)  # FIRE (executor mutator outside the lock)
        svc = self._workers[s]
        svc.flush()  # FIRE (worker touched outside its lock)
        with self._lock:
            self.count += 1  # guarded: fine
            self._workers[s].flush()  # guarded: fine
        self.count += 1  # repro: ignore[RPA002]

    def reset(self):
        self.count = 0  # FIRE (attr shared with the executor, unguarded)
        self.unrelated = 1  # not executor-shared: fine
