# unguarded wide shifts; any repro/ path (RPA004 is unscoped)
OFFSET_BITS = 40
RANK_BITS = 64 - OFFSET_BITS  # folds to 24


def pack(rank, offset):
    return (rank << OFFSET_BITS) | offset  # FIRE (no guard in scope)


def pack_literal_amount(rank, offset):
    return (rank << 32) | offset  # FIRE


def pack_suppressed(rank, offset):
    key = (rank << 33) | offset  # repro: ignore[RPA004]
    return key
