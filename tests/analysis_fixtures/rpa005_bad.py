# purity violations in traced functions; analyzed under
# repro/kernels/fixture.py
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

import jax.experimental.pallas as pl


@jax.jit
def scorer(x, state):
    print("tracing", x)  # FIRE (host print)
    v = x.sum().item()  # FIRE (host sync)
    y = float(x[0])  # FIRE (scalar coercion)
    z = np.sqrt(x)  # FIRE (host numpy constant-folds)
    state.counter = 1  # FIRE (python-side mutation)
    q = float(x[1])  # repro: ignore[RPA005]
    return v + y + z + q


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0  # ref store: fine
    t = time.time()  # FIRE (wall clock inside a pallas kernel)
    del t


def run(x):
    return pl.pallas_call(_kern, out_shape=None)(x)


@functools.partial(jax.jit, static_argnames=("k",))
def topk(x, k):
    return x.tolist()  # FIRE (host sync)
