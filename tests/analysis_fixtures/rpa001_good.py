# known-good codec surface: zero RPA001 findings expected under the
# virtual path repro/core/codecs_fixture.py
from repro.api.protocol import IvfBacked
from repro.core.codecs import IdCodec


class FullCodec(IdCodec):
    def encode(self, ids, universe, reserved=None):  # extra arg: defaulted
        return b""

    def decode(self, blob, universe):
        return []

    def size_bits(self, blob):
        return 0

    def gather(self, blob, offsets):
        return None


class PassThroughCodec(IdCodec):
    def encode(self, *args, **kwargs):  # pass-through signature accepted
        return b""

    def decode(self, blob, universe):
        return []

    def size_bits(self, blob):
        return 0


def route(index):
    if isinstance(index, IvfBacked):  # protocol check, not hasattr
        return "ivf"
    return "raw"
