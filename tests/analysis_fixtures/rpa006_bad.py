# broad-except violations; analyzed under repro/shard/router_fixture.py
def risky(work):
    try:
        work()
    except Exception:  # FIRE (broad, outside the allowlist)
        pass
    try:
        work()
    except (ValueError, Exception):  # FIRE (broad via tuple)
        pass
    try:
        work()
    except BaseException:  # FIRE
        pass
    try:
        work()
    except Exception:  # repro: ignore[RPA006]
        pass
