"""Serving loop + retrieval feature integration tests."""

import numpy as np
import pytest

from repro.configs import ARCH_IDS
from repro.data.synthetic import make_dataset
from repro.launch.serve import main as serve_main
from repro.retrieval.index import RetrievalIndex


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-1b", "zamba2-2.7b", "xlstm-1.3b",
                                  "olmoe-1b-7b", "whisper-medium", "qwen2-vl-7b"])
def test_serve_driver_generates(arch):
    out = serve_main(["--arch", arch, "--reduced", "--batch", "2",
                      "--prompt-len", "4", "--gen", "6"])
    assert out.shape == (6, 2)
    assert np.isfinite(out).all()


@pytest.mark.slow
def test_serve_decode_is_deterministic():
    a = serve_main(["--arch", "gemma3-1b", "--reduced", "--batch", "2",
                    "--prompt-len", "4", "--gen", "8"])
    b = serve_main(["--arch", "gemma3-1b", "--reduced", "--batch", "2",
                    "--prompt-len", "4", "--gen", "8"])
    np.testing.assert_array_equal(a, b)


def test_retrieval_index_end_to_end():
    base, queries = make_dataset("deep-like", 20_000, 64, seed=0)
    ri = RetrievalIndex(nlist=64, id_codec="roc").build(base)
    stats = ri.stats()
    assert stats["bits_per_id"] < stats["compact_bits"] - 2
    ids, _, _ = ri.search(base[:32], nprobe=8, topk=5)
    # self-retrieval: the query vector itself must come back first
    assert np.mean(ids[:, 0] == np.arange(32)) > 0.9


def test_retrieval_index_with_pq_codes():
    base, _ = make_dataset("sift-like", 20_000, 16, seed=0)
    ri = RetrievalIndex(nlist=32, id_codec="gap_ans", pq_m=8,
                        code_codec="polya").build(base)
    s = ri.stats()
    assert s["code_bits_per_element"] <= 8.2
    ids, _, _ = ri.search(base[:8], nprobe=8, topk=3)
    assert ids.shape == (8, 3)


def test_retrieval_index_is_spec_thin():
    """RetrievalIndex is now a thin composition over the api factory:
    any spec serves, and the whole thing persists as one RIDX artifact."""
    base, queries = make_dataset("deep-like", 3_000, 16, seed=0)
    ri = RetrievalIndex(spec="IVF32,PQ8x8,ids=roc,codes=polya").build(base)
    assert ri.index.spec == "IVF32,PQ8x8,ids=roc,codes=polya"
    ids0, d0, _ = ri.search(queries, topk=5, nprobe=8)
    blob = ri.save()
    ri2 = RetrievalIndex.load(blob)
    ids1, d1, _ = ri2.search(queries, topk=5, nprobe=8)
    np.testing.assert_array_equal(ids0, ids1)
    np.testing.assert_array_equal(d0, d1)
    # graph spec through the same front door
    rg = RetrievalIndex(spec="NSG8,ids=ef").build(base[:400])
    gids, _, gst = rg.search(queries, topk=5, ef=16)
    assert gids.shape == (16, 5) and gst.visited > 0
    assert rg.stats()["bits_per_edge"] > 0


def test_ivf_container_roundtrip():
    """Offline whole-index blob (paper §4.3) round-trips and shrinks."""
    from repro.ann.ivf import IVFIndex
    from repro.ann.pq import ProductQuantizer
    from repro.core.container import pack_ivf, unpack_ivf

    base, _ = make_dataset("sift-like", 30_000, 8, seed=0)
    pq = ProductQuantizer(m=8, bits=8)
    idx = IVFIndex(nlist=64, id_codec="compact", pq=pq,
                   code_codec="polya").build(base)
    blob = pack_ivf(idx)
    manifest, lists, cents, codes = unpack_ivf(blob)
    assert manifest["n"] == 30_000
    for k in range(64):
        np.testing.assert_array_equal(lists[k], np.sort(idx._lists[k]))
    np.testing.assert_array_equal(codes, idx.codes)
    np.testing.assert_allclose(cents, idx.centroids, atol=0.5)
    # blob must beat the compact layout (ids at ceil(log2 n) + raw codes)
    compact_bytes = (np.ceil(np.log2(30_000)) / 8) * 30_000 + 30_000 * 8
    assert len(blob) < compact_bytes


def test_public_import_surface():
    """The documented package entry points all import."""
    import repro.core as core
    import repro.serve as serve
    from repro.api import (Index, index_factory, load_index,  # noqa: F401
                           parse_spec, save_index)
    from repro.core import CODEC_NAMES, get_codec
    from repro.distributed.sp import sp_decode_attention  # noqa: F401
    from repro.serve import make_prefill_step, make_serve_step  # noqa: F401

    assert set(CODEC_NAMES) >= {"unc64", "compact", "ef", "roc", "gap_ans"}
    for name in CODEC_NAMES:
        assert get_codec(name) is not None
