"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU; asserts output shapes and no NaNs (assignment contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import build, count_params
from repro.models.encdec import dec_len_for

jax.config.update("jax_platforms", "cpu")

B, S = 2, 64


def _batch_for(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.encoder_decoder:
        Sd = dec_len_for(S)
        return {
            "frames": jax.random.normal(ks[0], (B, S, cfg.d_model)),
            "dec_tokens": jax.random.randint(ks[1], (B, Sd), 0, cfg.vocab_size),
        }, (B, Sd)
    if cfg.frontend == "vision":
        return {
            "embeddings": jax.random.normal(ks[0], (B, S, cfg.d_model)),
            "positions": jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S)),
        }, (B, S)
    return {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}, (B, S)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch, (b, s) = _batch_for(cfg, jax.random.PRNGKey(1))
    logits, aux = model.apply(params, **batch, remat=False)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nan(arch):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, (b, s) = _batch_for(cfg, jax.random.PRNGKey(1))
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)

    def loss_fn(p):
        logits, aux = model.apply(p, **batch, remat=True)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    assert bool(jnp.isfinite(gnorm)), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 32, dtype=jnp.float32)
    if cfg.frontend == "vision":
        inputs = {"embedding": jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.d_model))}
    else:
        inputs = {"token": jnp.zeros((B, 1), jnp.int32)}
    logits, cache2 = model.decode_step(params, cache, **inputs)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode logits"
    logits3, _ = model.decode_step(params, cache2, **inputs)
    assert bool(jnp.isfinite(logits3).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_sane(arch):
    """eval_shape over the FULL config (no allocation) — catches shape bugs."""
    cfg = get_config(arch)
    n = count_params(cfg)
    # coarse sanity bands from the arch names (e.g. 20b -> [10e9, 40e9])
    bands = {
        "granite-20b": (15e9, 28e9),
        "minitron-4b": (3e9, 6.5e9),
        "qwen2-72b": (60e9, 85e9),
        "gemma3-1b": (0.7e9, 1.8e9),
        "zamba2-2.7b": (2.0e9, 4.5e9),
        "whisper-medium": (0.25e9, 1.0e9),
        "llama4-scout-17b-a16e": (80e9, 130e9),
        "olmoe-1b-7b": (5e9, 9e9),
        "xlstm-1.3b": (1.0e9, 2.5e9),
        "qwen2-vl-7b": (6e9, 9e9),
    }
    lo, hi = bands[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of band"


def test_blocked_attention_matches_dense():
    """Flash-style blocked path == dense reference (hillclimb #1 oracle)."""
    import numpy as np
    from repro.models import attention as A

    rng = np.random.default_rng(0)
    cfg = reduced(get_config("minitron-4b"))
    B_, S_, H, KV, D = 2, 512, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B_, S_, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B_, S_, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B_, S_, KV, D)), jnp.float32)
    A_BQ, A_BKV = A._BLOCK_Q, A._BLOCK_KV
    A._BLOCK_Q, A._BLOCK_KV = 128, 128
    for causal, window in [(True, 0), (True, 64), (False, 0)]:
        mask = A._causal_mask(S_, S_, window) if causal else None
        ref = A._sdpa(q, k, v, mask, cfg)
        blk = A._sdpa_blocked(q, k, v, cfg, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
    A._BLOCK_Q, A._BLOCK_KV = A_BQ, A_BKV


def test_blocked_attention_uneven_chunks():
    import numpy as np
    from repro.models import attention as A

    rng = np.random.default_rng(1)
    cfg = reduced(get_config("granite-20b"))
    q = jnp.asarray(rng.standard_normal((1, 300, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 300, 1, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 300, 1, 16)), jnp.float32)
    A_BQ, A_BKV = A._BLOCK_Q, A._BLOCK_KV
    A._BLOCK_Q, A._BLOCK_KV = 128, 128
    ref = A._sdpa(q, k, v, A._causal_mask(300, 300, 0), cfg)
    blk = A._sdpa_blocked(q, k, v, cfg, causal=True)
    A._BLOCK_Q, A._BLOCK_KV = A_BQ, A_BKV
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
