"""Segmented top-k select (``repro.kernels.seg_topk``): the Pallas kernel,
the ``lax.top_k`` fallback and the stable-argsort oracle must be
bit-identical — values AND columns — on every edge the scan layer hits:
k past the segment length, empty segments, all-inf rows, k=1, tie pileups.
Plus the flat brute-force consumer (``batched_flat_search``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.seg_topk import (SEG_BLOCK_Q, seg_topk, seg_topk_ref,
                                    seg_topk_xla)

jax.config.update("jax_platforms", "cpu")


def _check_all(dists, lens, k):
    """All three engines agree exactly; returns (vals, idx)."""
    d = jnp.asarray(dists, jnp.float32)
    ln = jnp.asarray(lens, jnp.int32)
    vr, ir = seg_topk_ref(d, ln, k)
    vx, ix = seg_topk_xla(d, ln, k)
    vp, ip = seg_topk(d, ln, k)
    np.testing.assert_array_equal(np.asarray(vx), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    return np.asarray(vr), np.asarray(ir)


@pytest.mark.parametrize("nq,n,k", [(8, 64, 10), (3, 200, 16), (16, 130, 1),
                                    (1, 7, 4), (5, 33, 33)])
def test_engines_bit_identical_random(nq, n, k):
    rng = np.random.default_rng(0)
    d = rng.standard_normal((nq, n)).astype(np.float32)
    lens = rng.integers(0, n + 1, size=nq)
    vals, idx = _check_all(d, lens, k)
    assert vals.shape == (nq, k) and idx.shape == (nq, k)
    # ascending values (inf <= inf holds; np.diff would produce nan)
    assert np.all(vals[:, :-1] <= vals[:, 1:])


def test_k_exceeds_segment_length():
    """Rows shorter than k: real candidates first, +inf padding after,
    padding columns are the lowest masked ones (lax.top_k tie order)."""
    d = np.arange(12, dtype=np.float32).reshape(2, 6)
    lens = np.array([3, 0])
    vals, idx = _check_all(d, lens, 5)
    np.testing.assert_array_equal(idx[0], [0, 1, 2, 3, 4])
    np.testing.assert_array_equal(vals[0], [0, 1, 2, np.inf, np.inf])
    # empty segment: everything is padding, columns ascend from 0
    np.testing.assert_array_equal(idx[1], [0, 1, 2, 3, 4])
    assert np.all(np.isinf(vals[1]))


def test_k_exceeds_row_width():
    """k > N: the row itself must be widened with masked columns."""
    d = np.array([[3.0, 1.0, 2.0]], np.float32)
    vals, idx = _check_all(d, np.array([3]), 6)
    np.testing.assert_array_equal(idx[0, :3], [1, 2, 0])
    np.testing.assert_array_equal(vals[0, :3], [1.0, 2.0, 3.0])
    assert np.all(np.isinf(vals[0, 3:]))


def test_all_inf_rows():
    """Genuine +inf distances tie with the mask; column order must still
    be ascending and identical across engines (the scan layer separates
    real hits from padding by ``idx < lens``)."""
    d = np.full((4, 8), np.inf, np.float32)
    lens = np.array([8, 3, 0, 5])
    vals, idx = _check_all(d, lens, 4)
    for row in idx:
        np.testing.assert_array_equal(row, [0, 1, 2, 3])
    assert np.all(np.isinf(vals))


def test_k_one_and_ties():
    d = np.array([[2.0, 1.0, 1.0, 5.0],
                  [7.0, 7.0, 7.0, 7.0]], np.float32)
    vals, idx = _check_all(d, np.array([4, 4]), 1)
    np.testing.assert_array_equal(idx[:, 0], [1, 0])   # ties -> lower column
    np.testing.assert_array_equal(vals[:, 0], [1.0, 7.0])


def test_tie_pileup_order():
    """Many equal values: selection must walk columns left to right."""
    d = np.zeros((2, 50), np.float32)
    d[1, :10] = -1.0
    vals, idx = _check_all(d, np.array([50, 50]), 12)
    np.testing.assert_array_equal(idx[0], np.arange(12))
    np.testing.assert_array_equal(idx[1], np.arange(12))


def test_block_q_boundary_shapes():
    """nq not a multiple of the kernel block: padding rows must not leak."""
    rng = np.random.default_rng(1)
    for nq in (1, SEG_BLOCK_Q - 1, SEG_BLOCK_Q, SEG_BLOCK_Q + 3):
        d = rng.standard_normal((nq, 40)).astype(np.float32)
        _check_all(d, np.full(nq, 40), 5)


def test_empty_batch_and_k_zero():
    d = jnp.zeros((0, 16), jnp.float32)
    vals, idx = seg_topk(d, jnp.zeros(0, jnp.int32), 4)
    assert vals.shape == (0, 4) and idx.shape == (0, 4)
    d2 = jnp.zeros((3, 16), jnp.float32)
    vals2, idx2 = seg_topk_xla(d2, jnp.full(3, 16, jnp.int32), 0)
    assert vals2.shape == (3, 0) and idx2.shape == (3, 0)


# ---------------------------------------------------------------------------
# flat brute-force consumer
# ---------------------------------------------------------------------------

def _flat_oracle(vecs, queries, topk):
    from repro.ann.scan import score_rows_flat, select_topk

    ids = np.zeros((queries.shape[0], topk), np.int64)
    dists = np.full((queries.shape[0], topk), np.inf, np.float32)
    k_eff = min(topk, vecs.shape[0])
    for qi, q in enumerate(queries):
        d = score_rows_flat(vecs, q)
        sel = select_topk(d, k_eff)
        ids[qi, :k_eff] = sel
        dists[qi, :k_eff] = d[sel]
    return ids, dists


@pytest.mark.parametrize("engine", ["xla", "pallas"])
def test_batched_flat_search_parity(engine):
    from repro.ann.scan import batched_flat_search

    rng = np.random.default_rng(2)
    vecs = rng.standard_normal((700, 24)).astype(np.float32)
    vecs[10] = vecs[5]                       # duplicate rows: tie stress
    vecs[11] = vecs[5]
    queries = rng.standard_normal((19, 24)).astype(np.float32)
    queries[0] = vecs[5]
    ref_ids, ref_d = _flat_oracle(vecs, queries, 10)
    ids, dists, st = batched_flat_search(vecs, queries, topk=10,
                                         engine=engine, query_block=8)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(dists, ref_d)
    assert st.engine == f"flat-{engine}"
    assert st.device_select == st.batches > 0
    # the (qb, n_pad) block never crossed: pulled bytes stay shortlist-sized
    assert st.host_block_bytes < vecs.shape[0] * queries.shape[0] * 4


def test_batched_flat_search_topk_exceeds_n():
    from repro.ann.scan import batched_flat_search

    rng = np.random.default_rng(3)
    vecs = rng.standard_normal((6, 8)).astype(np.float32)
    queries = rng.standard_normal((4, 8)).astype(np.float32)
    ref_ids, ref_d = _flat_oracle(vecs, queries, 10)
    ids, dists, _ = batched_flat_search(vecs, queries, topk=10)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(dists, ref_d)


@pytest.mark.parametrize("engine", ["xla", "pallas"])
def test_flat_api_index_engine_path(engine):
    """``Flat,engine=...`` specs route through the kernel path and stay
    bit-identical to the legacy numpy loop (id_map remap included)."""
    from repro.api import index_factory

    rng = np.random.default_rng(4)
    vecs = rng.standard_normal((300, 16)).astype(np.float32)
    queries = rng.standard_normal((9, 16)).astype(np.float32)
    legacy = index_factory("Flat").build(vecs)
    fast = index_factory(f"Flat,engine={engine}").build(vecs)
    d_ref, i_ref, st_ref = legacy.search(queries, k=5)
    d, i, st = fast.search(queries, k=5)
    np.testing.assert_array_equal(i, i_ref)
    np.testing.assert_array_equal(d, d_ref)
    assert st_ref.engine == "flat" and st.engine == f"flat-{engine}"
    assert st.device_select > 0
