"""Deterministic codec edge cases — the ``IdCodec`` contract, no hypothesis.

Codifies what every registry codec must do with the degenerate inputs the
index layer can produce: the empty list, a single id, the full universe,
``universe == 1``, plus blob-level byte-exactness for the stream codecs
(ROC / gap-ANS) and the ``size_bits`` accounting contract.
"""

import numpy as np
import pytest

from repro.core.codecs import CODEC_NAMES, get_codec
from repro.core.wavelet_tree import WaveletTree

EDGE_CASES = [
    ("empty", np.zeros(0, np.int64), 100),
    ("single", np.array([7], np.int64), 100),
    ("single-last", np.array([99], np.int64), 100),
    ("full-universe", np.arange(50, dtype=np.int64), 50),
    ("universe-1", np.array([0], np.int64), 1),
    ("two-adjacent", np.array([41, 40], np.int64), 100),  # unsorted input
]


@pytest.mark.parametrize("name", CODEC_NAMES)
@pytest.mark.parametrize("label,ids,universe",
                         EDGE_CASES, ids=[c[0] for c in EDGE_CASES])
def test_codec_edge_roundtrip(name, label, ids, universe):
    codec = get_codec(name)
    blob = codec.encode(ids, universe)
    out = np.asarray(codec.decode(blob, universe))
    np.testing.assert_array_equal(out, np.sort(ids))
    assert out.dtype == np.int64 or out.size == 0


@pytest.mark.parametrize("name", CODEC_NAMES)
@pytest.mark.parametrize("label,ids,universe",
                         EDGE_CASES, ids=[c[0] for c in EDGE_CASES])
def test_codec_size_bits_contract(name, label, ids, universe):
    """size_bits is a non-negative payload figure, exact for word codecs."""
    codec = get_codec(name)
    blob = codec.encode(ids, universe)
    bits = codec.size_bits(blob)
    assert bits >= 0
    n = len(ids)
    if name == "unc64":
        assert bits == 64 * n
    elif name == "unc32":
        assert bits == 32 * n
    elif name == "compact":
        import math

        assert bits == max(1, math.ceil(math.log2(max(2, universe)))) * n


@pytest.mark.parametrize("name", CODEC_NAMES)
@pytest.mark.parametrize("label,ids,universe",
                         EDGE_CASES, ids=[c[0] for c in EDGE_CASES])
def test_codec_gather_contract(name, label, ids, universe):
    """Random-access codecs gather sorted-position offsets; stream codecs
    return None (callers decode through the LRU cache instead)."""
    codec = get_codec(name)
    blob = codec.encode(ids, universe)
    offs = np.arange(len(ids), dtype=np.int64)
    got = codec.gather(blob, offs)
    if name in ("roc", "gap_ans"):
        assert got is None
    else:
        np.testing.assert_array_equal(got, np.sort(ids))


@pytest.mark.parametrize("n,universe", [(0, 10), (1, 10), (37, 1000),
                                        (256, 256)])
def test_roc_blob_byte_exact_roundtrip(n, universe):
    """encode -> decode -> encode reproduces the exact ANS byte stream."""
    rng = np.random.default_rng(6)
    ids = rng.choice(universe, size=n, replace=False)
    codec = get_codec("roc")
    blob = codec.encode(ids, universe)
    out = codec.decode(blob, universe)
    blob2 = codec.encode(out, universe)
    assert blob["state"] == blob2["state"]
    assert blob["n"] == blob2["n"]


@pytest.mark.parametrize("n,universe", [(0, 10), (1, 10), (37, 1000),
                                        (900, 1000)])
def test_gap_ans_blob_byte_exact_roundtrip(n, universe):
    rng = np.random.default_rng(7)
    ids = rng.choice(universe, size=n, replace=False)
    codec = get_codec("gap_ans")
    blob = codec.encode(ids, universe)
    out = codec.decode(blob, universe)
    blob2 = codec.encode(out, universe)
    np.testing.assert_array_equal(blob["heads"], blob2["heads"])
    np.testing.assert_array_equal(blob["words"], blob2["words"])
    assert blob["k"] == blob2["k"] and blob["n"] == blob2["n"]


# ---------------------------------------------------------------------------
# wavelet-tree edges (the joint structure is not in the registry)
# ---------------------------------------------------------------------------

def test_wavelet_tree_single_symbol_universe():
    wt = WaveletTree.build(np.zeros(10, np.int64), 1)
    assert wt.cluster_size(0) == 10
    assert [wt.select(0, i) for i in range(10)] == list(range(10))


def test_wavelet_tree_empty_cluster():
    s = np.array([0, 0, 2, 2, 2, 0])
    wt = WaveletTree.build(s, 3)
    assert wt.cluster_size(1) == 0
    np.testing.assert_array_equal(wt.decode_cluster(1),
                                  np.zeros(0, np.int64))
    np.testing.assert_array_equal(wt.decode_cluster(2), [2, 3, 4])


def test_wavelet_tree_empty_string():
    wt = WaveletTree.build(np.zeros(0, np.int64), 4)
    assert wt.size_bits == 0 and wt.length == 0
