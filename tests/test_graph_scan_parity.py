"""Batched graph-scan parity: ``GraphIndex.search`` must be bit-identical
to the ``search_ref`` oracle — ids AND distances — for NSG and HNSW, every
graph id codec, both scoring engines, every kernel-gate setting, and
across edge cases (single query, ef=1, topk > n, duplicate vectors,
post-``add()`` indexes, RIDX-reloaded indexes).

Also: beam-state invariant property tests (hypothesis, with the
deterministic fallback) and the DecodedListCache exact-count test shared
by the IVF and graph paths.
"""

import numpy as np
import pytest

import jax

try:  # hypothesis is optional (tests/requirements-test.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:  # properties run over deterministic seeded samples
    from _compat_hypothesis import given, settings, st

from repro.ann.graph import GraphIndex, build_hnsw, build_nsg
from repro.ann.graph_scan import GRAPH_BLOCK_N, batched_graph_search
from repro.ann.scan import DecodedListCache

jax.config.update("jax_platforms", "cpu")

ALL_CODECS = ["unc64", "unc32", "compact", "ef", "roc", "gap_ans"]
ENGINES = ["xla", "pallas"]


def _data(n=800, d=24, nq=33, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((n, d)).astype(np.float32)
    base[50] = base[51]          # duplicate vectors -> exact distance ties
    base[52] = base[51]
    queries = rng.standard_normal((nq, d)).astype(np.float32)
    queries[5] = queries[6]      # duplicate queries too
    return base, queries


@pytest.fixture(scope="module")
def data():
    return _data()


@pytest.fixture(scope="module")
def graphs(data):
    base, _ = data
    return {"nsg": build_nsg(base, 12, seed=3),
            "hnsw": build_hnsw(base, 8, seed=3)}


def _assert_parity(idx, queries, ef=24, topk=10, engine="xla", **kw):
    ids_r, d_r, _ = idx.search_ref(queries, ef=ef, topk=topk)
    ids_b, d_b, st_b = idx.search(queries, ef=ef, topk=topk,
                                  engine=engine, **kw)
    np.testing.assert_array_equal(ids_b, ids_r)
    np.testing.assert_array_equal(d_b, d_r)       # exact, not allclose
    return st_b


# ---------------------------------------------------------------------------
# codec x builder x engine matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ALL_CODECS)
@pytest.mark.parametrize("kind", ["nsg", "hnsw"])
def test_parity_all_codecs(data, graphs, kind, codec):
    base, queries = data
    idx = GraphIndex(id_codec=codec).build(base, graphs[kind])
    # kernel_min forces the device-scorer branch on CPU too
    _assert_parity(idx, queries, kernel_min=GRAPH_BLOCK_N)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("kind", ["nsg", "hnsw"])
def test_parity_engines(data, graphs, kind, engine):
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs[kind])
    _assert_parity(idx, queries, engine=engine, kernel_min=GRAPH_BLOCK_N)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("codec", ["compact", "gap_ans"])
def test_parity_codec_engine_cross(data, graphs, codec, engine):
    base, queries = data
    idx = GraphIndex(id_codec=codec).build(base, graphs["nsg"])
    _assert_parity(idx, queries, engine=engine, kernel_min=GRAPH_BLOCK_N)


def test_parity_kernel_gate_settings(data, graphs):
    """The kernel_min gate is a pure perf knob: results identical whether
    every step, some steps, or no step takes the device scorer."""
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs["nsg"])
    ids_r, d_r, _ = idx.search_ref(queries, ef=24, topk=10)
    for km in (None, 1, GRAPH_BLOCK_N, 10**9):
        ids_b, d_b, _ = idx.search(queries, ef=24, topk=10, kernel_min=km)
        np.testing.assert_array_equal(ids_b, ids_r)
        np.testing.assert_array_equal(d_b, d_r)


# ---------------------------------------------------------------------------
# device-side step select (select="device")
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("kind", ["nsg", "hnsw"])
def test_parity_device_select(data, graphs, kind, engine):
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs[kind])
    st = _assert_parity(idx, queries, engine=engine, kernel_min=1,
                        select="device")
    # every kernel-scored step gathered its distances on device, and only
    # the per-candidate vectors (not the step blocks) crossed to the host
    assert st.device_select > 0
    _, _, st_h = idx.search(queries, ef=24, topk=10, engine=engine,
                            kernel_min=1, select="host")
    assert st_h.device_select == 0
    assert 0 < st.host_block_bytes < st_h.host_block_bytes


@pytest.mark.parametrize("codec", ["compact", "gap_ans"])
def test_parity_device_select_codecs(data, graphs, codec):
    base, queries = data
    idx = GraphIndex(id_codec=codec).build(base, graphs["nsg"])
    _assert_parity(idx, queries, kernel_min=1, select="device")


def test_graph_select_unknown_mode_raises(data, graphs):
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs["nsg"])
    with pytest.raises(ValueError, match="select"):
        idx.search(queries[:2], select="gpu")


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_parity_single_query(data, graphs):
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs["nsg"])
    _assert_parity(idx, queries[:1], kernel_min=GRAPH_BLOCK_N)


def test_parity_ef_one(data, graphs):
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs["hnsw"])
    _assert_parity(idx, queries, ef=1, topk=1)


def test_parity_topk_exceeds_n(data, graphs):
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs["nsg"])
    _assert_parity(idx, queries, ef=4, topk=2 * base.shape[0])


def test_parity_small_query_block(data, graphs):
    """Batching contract: results independent of query_block."""
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs["nsg"])
    ref = idx.search(queries, ef=24, topk=10)
    for qb in (1, 7, 64):
        got = idx.search(queries, ef=24, topk=10, query_block=qb)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


def test_parity_after_add(data, graphs):
    base, queries = data
    idx = GraphIndex(id_codec="ef").build(base[:700],
                                          [a[a < 700] for a in
                                           graphs["nsg"][:700]])
    idx.add(base[700:], r=12)
    _assert_parity(idx, queries, kernel_min=GRAPH_BLOCK_N)


def test_parity_reloaded_ridx_index(data):
    from repro.api import index_factory, load_index, save_index

    base, queries = data
    idx = index_factory("NSG12,ids=roc").build(base, seed=1)
    idx2 = load_index(save_index(idx))
    ids_r, d_r, _ = idx.graph.search_ref(queries, ef=24, topk=10)
    ids_b, d_b, st = idx2.graph.search(queries, ef=24, topk=10,
                                       kernel_min=GRAPH_BLOCK_N)
    np.testing.assert_array_equal(ids_b, ids_r)
    np.testing.assert_array_equal(d_b, d_r)
    assert st.engine.startswith("graph-")


def test_batched_stats_counters(data, graphs):
    base, queries = data
    idx = GraphIndex(id_codec="roc").build(base, graphs["nsg"])
    _assert_parity(idx, queries)
    # the oracle pass above warmed the shared cache; clear the entries
    # (counters survive) so the batched pass's decode delta is visible
    idx.decoded_cache.clear()
    _, _, st = idx.search(queries, ef=24, topk=10)
    assert st.steps > 0
    # every step counts its active beams; at least one beam runs per step
    assert st.frontier_size >= st.steps
    assert st.visited > 0 and st.ndis >= st.visited
    assert st.dedup_hits >= 0
    # the per-block memo decodes each distinct expanded node at most once
    assert 0 < st.decodes <= st.visited - st.dedup_hits


# ---------------------------------------------------------------------------
# beam-state invariant properties
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31), st.integers(1, 48), st.integers(1, 12))
@settings(max_examples=10, deadline=None)
def test_property_results_well_formed(seed, ef, topk):
    """No id appears twice in a result row; distances sorted ascending;
    batched == reference for random (seed, ef, topk)."""
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((300, 8)).astype(np.float32)
    queries = rng.standard_normal((9, 8)).astype(np.float32)
    idx = GraphIndex(id_codec="roc").build(base, build_nsg(base, 6, seed=1))
    ids_r, d_r, _ = idx.search_ref(queries, ef=ef, topk=topk)
    ids_b, d_b, _ = batched_graph_search(idx, queries, ef=ef, topk=topk)
    np.testing.assert_array_equal(ids_b, ids_r)
    np.testing.assert_array_equal(d_b, d_r)
    k = min(topk, ef)
    for row_ids, row_d in zip(ids_b[:, :k], d_b[:, :k]):
        finite = row_d < np.inf
        assert len(set(row_ids[finite].tolist())) == int(finite.sum())
        assert np.all(np.diff(row_d[finite]) >= 0)


@given(st.integers(0, 2**31), st.integers(2, 32))
@settings(max_examples=5, deadline=None)
def test_property_beam_state_invariants(seed, ef):
    """Step-level invariants of the array bookkeeping, checked at every
    pop: visited counts only grow, frontier slots past f_len stay +inf,
    beam lengths never exceed ef, and b_max matches the live beam max."""
    import repro.ann.graph_scan as gs

    rng = np.random.default_rng(seed)
    base = rng.standard_normal((300, 8)).astype(np.float32)
    queries = rng.standard_normal((8, 8)).astype(np.float32)
    idx = GraphIndex(id_codec="roc").build(base, build_nsg(base, 6, seed=1))

    seen = {"last_visited": -1, "checks": 0}
    orig = gs._BeamState.pop_all

    def checked_pop(self):
        v = int(self.visited.sum())
        assert v >= seen["last_visited"]          # monotone visited sets
        seen["last_visited"] = v
        cols = np.arange(self.f_d.shape[1])[None, :]
        pad = cols >= self.f_len[:, None]
        assert np.all(np.isinf(self.f_d[pad]))    # frontier pad invariant
        assert np.all(self.b_len <= self.ef)
        full = np.flatnonzero(self.b_len == self.ef)
        for i in full[:4]:                        # spot-check b_max cache
            assert self.b_max[i] == self.b_d[i, :self.ef].max()
        seen["checks"] += 1
        return orig(self)

    # plain patch (not the monkeypatch fixture: function-scoped fixtures
    # are rejected inside @given by hypothesis health checks)
    gs._BeamState.pop_all = checked_pop
    try:
        ids_b, d_b, _ = batched_graph_search(idx, queries, ef=ef, topk=5)
    finally:
        gs._BeamState.pop_all = orig
    assert seen["checks"] > 0
    ids_r, d_r, _ = idx.search_ref(queries, ef=ef, topk=5)
    np.testing.assert_array_equal(ids_b, ids_r)
    np.testing.assert_array_equal(d_b, d_r)


# ---------------------------------------------------------------------------
# DecodedListCache: exact hit/miss/eviction accounting
# ---------------------------------------------------------------------------

def test_decoded_cache_exact_counts():
    """Forced-eviction budget: every counter lands exactly where the LRU
    spec says, including the set_budget shrink path."""
    entry = np.arange(10, dtype=np.int64)         # 80 bytes each
    cache = DecodedListCache(max_bytes=160)       # room for two entries
    mk = lambda: entry.copy()
    cache.get(0, mk)                              # miss           [0]
    cache.get(1, mk)                              # miss           [0, 1]
    cache.get(0, mk)                              # hit            [1, 0]
    cache.get(2, mk)                              # miss, evict 1  [0, 2]
    cache.get(1, mk)                              # miss, evict 0  [2, 1]
    assert cache.stats() == {"entries": 2, "bytes": 160, "hits": 1,
                             "decodes": 4, "evictions": 2}
    cache.set_budget(100)                         # shrink: evict 2 -> [1]
    assert cache.stats() == {"entries": 1, "bytes": 80, "hits": 1,
                             "decodes": 4, "evictions": 3}


def test_decoded_cache_shared_by_both_paths(data, graphs):
    """IVF and graph searches account decode traffic through the same
    DecodedListCache class with the same counters."""
    from repro.ann.ivf import IVFIndex

    base, queries = data
    g = GraphIndex(id_codec="roc").build(base, graphs["nsg"])
    ivf = IVFIndex(nlist=8, id_codec="roc").build(base, seed=1)
    assert isinstance(g.decoded_cache, DecodedListCache)
    assert isinstance(ivf.decoded_cache, DecodedListCache)
    g.search(queries, ef=8, topk=4)
    ivf.search(queries, nprobe=2, topk=4)
    assert g.decoded_cache.stats()["decodes"] > 0
    assert ivf.decoded_cache.stats()["decodes"] > 0
