"""Runner for the multi-device test module.

The main pytest process must keep the default single CPU device (smoke
tests and benches see 1 device per the dry-run contract), so the 8-device
tests in tests/test_distributed.py execute in a subprocess with
``--xla_force_host_platform_device_count=8`` set before jax imports.
"""

import os
import subprocess
import sys
from pathlib import Path


def test_distributed_suite_in_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-m", "pytest",
         str(Path(__file__).parent / "test_distributed.py"), "-q",
         "--no-header", "-p", "no:cacheprovider"],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert out.returncode == 0, f"\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "skipped" not in out.stdout.splitlines()[-1] or "passed" in out.stdout
