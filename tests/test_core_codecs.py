"""Tests for the set codecs (ROC, EF, gap-ANS), WT, RRR, REC, Polya, webgraph."""

import numpy as np
import pytest

try:  # hypothesis is optional (tests/requirements-test.txt): without it the
    from hypothesis import given, settings, strategies as st
except ImportError:  # properties run over deterministic seeded samples
    from _compat_hypothesis import given, settings, st

from repro.core import (
    BigANS,
    EliasFano,
    WaveletTree,
    decode_gaps,
    encode_gaps,
    get_codec,
    polya_decode_clusters,
    polya_encode_clusters,
    rec_decode,
    rec_encode,
    roc_pop_set,
    roc_push_set,
    set_information_bits,
)
from repro.core.bitvec import BitVector, pack_lowbits, unpack_lowbits
from repro.core.rrr import RRRVector
from repro.core.webgraph_lite import webgraph_decode, webgraph_encode


def _random_set(rng, n, universe):
    return rng.choice(universe, size=n, replace=False)


# ---------------------------------------------------------------------------
# ROC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,universe", [(1, 100), (50, 1000), (700, 10_000), (1000, 2**20)])
def test_roc_roundtrip(n, universe):
    rng = np.random.default_rng(42)
    ids = _random_set(rng, n, universe)
    ans = BigANS()
    roc_push_set(ans, ids, universe)
    out = roc_pop_set(ans, n, universe)
    np.testing.assert_array_equal(out, np.sort(ids))
    assert ans.state == 0


def test_roc_rate_matches_set_bound():
    """The headline claim: ROC ~= log2 C(N, n) bits, i.e. n log N - log n!."""
    rng = np.random.default_rng(7)
    universe, n = 1_000_000, 1000
    ids = _random_set(rng, n, universe)
    ans = BigANS()
    roc_push_set(ans, ids, universe)
    bound = set_information_bits(universe, n)
    assert bound <= ans.bits <= bound + 8  # exact coder: within a few bits


def test_roc_beats_compact_by_log_n_factorial():
    # paper Table 1: IVF1024-ish cluster, expect ~11.4 bpe vs compact 20
    rng = np.random.default_rng(8)
    universe, n = 1_000_000, 977
    ids = _random_set(rng, n, universe)
    ans = BigANS()
    roc_push_set(ans, ids, universe)
    bpe = ans.bits / n
    assert 11.0 < bpe < 11.8


def test_roc_large_cluster_fenwick_path():
    rng = np.random.default_rng(9)
    universe, n = 100_000, 4000  # > 512 triggers the Fenwick path
    ids = _random_set(rng, n, universe)
    ans = BigANS()
    roc_push_set(ans, ids, universe)
    out = roc_pop_set(ans, n, universe)
    np.testing.assert_array_equal(out, np.sort(ids))


def test_roc_rejects_duplicates():
    ans = BigANS()
    with pytest.raises(ValueError):
        roc_push_set(ans, np.array([1, 1, 2]), 10)


@given(st.integers(0, 2**31), st.integers(1, 300))
@settings(max_examples=25, deadline=None)
def test_roc_property(seed, n):
    rng = np.random.default_rng(seed)
    universe = int(rng.integers(n, n * 50 + 2))
    ids = _random_set(rng, n, universe)
    ans = BigANS()
    roc_push_set(ans, ids, universe)
    np.testing.assert_array_equal(roc_pop_set(ans, n, universe), np.sort(ids))
    assert ans.state == 0


# ---------------------------------------------------------------------------
# Elias-Fano
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,universe", [(10, 100), (977, 1_000_000), (5000, 2**20)])
def test_ef_roundtrip_and_rate(n, universe):
    rng = np.random.default_rng(10)
    ids = np.sort(_random_set(rng, n, universe))
    ef = EliasFano.encode(ids, universe)
    np.testing.assert_array_equal(ef.decode(), ids)
    # EF is within ~2.56 bits/id of the set bound (2 unary + ~0.56)
    bound = set_information_bits(universe, n) / n
    assert bound <= ef.size_bits / n <= bound + 2.6


def test_ef_random_access():
    rng = np.random.default_rng(11)
    ids = np.sort(_random_set(rng, 500, 10_000))
    ef = EliasFano.encode(ids, 10_000)
    for i in [0, 1, 250, 499]:
        assert ef.access(i) == ids[i]


# ---------------------------------------------------------------------------
# gap-ANS (TPU-path codec)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,universe,lanes", [
    (1, 100, 4), (64, 1000, 64), (977, 1_000_000, 64), (3000, 2**20, 128),
])
def test_gap_ans_roundtrip(n, universe, lanes):
    rng = np.random.default_rng(12)
    ids = _random_set(rng, n, universe)
    heads, words, k = encode_gaps(ids, universe, lanes)
    out = decode_gaps(heads, words, k, n, lanes)
    np.testing.assert_array_equal(out, np.sort(ids))


def test_gap_ans_rate_near_set_bound():
    rng = np.random.default_rng(13)
    universe, n = 1_000_000, 977
    ids = _random_set(rng, n, universe)
    from repro.core.gap_ans import GapAnsCodec
    gc = GapAnsCodec()
    blob = gc.encode(ids, universe)
    bits = gc.size_bits(blob)
    bound = set_information_bits(universe, n)
    # within ~2 bits/id of the set bound incl. 32-bit lane-head overhead
    assert bits <= bound + 2.0 * n


def test_gap_ans_dense_set():
    # dense regime: n close to universe (tiny gaps, k=0)
    rng = np.random.default_rng(14)
    ids = _random_set(rng, 900, 1000)
    heads, words, k = encode_gaps(ids, 1000, 16)
    out = decode_gaps(heads, words, k, 900, 16)
    np.testing.assert_array_equal(out, np.sort(ids))


@given(st.integers(0, 2**31), st.integers(1, 400))
@settings(max_examples=25, deadline=None)
def test_gap_ans_property(seed, n):
    rng = np.random.default_rng(seed)
    universe = int(rng.integers(n, n * 100 + 2))
    ids = _random_set(rng, n, universe)
    heads, words, k = encode_gaps(ids, universe, 32)
    np.testing.assert_array_equal(
        decode_gaps(heads, words, k, n, 32), np.sort(ids)
    )


# ---------------------------------------------------------------------------
# codec registry facade
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["unc64", "unc32", "compact", "ef", "roc", "gap_ans"])
def test_codec_registry_roundtrip(name):
    rng = np.random.default_rng(15)
    universe, n = 50_000, 333
    ids = _random_set(rng, n, universe)
    codec = get_codec(name)
    blob = codec.encode(ids, universe)
    np.testing.assert_array_equal(codec.decode(blob, universe), np.sort(ids))
    assert codec.size_bits(blob) > 0


# ---------------------------------------------------------------------------
# BitVector / RRR
# ---------------------------------------------------------------------------

def test_bitvector_rank_select():
    rng = np.random.default_rng(16)
    bits = (rng.random(10_000) < 0.3).astype(np.uint8)
    bv = BitVector.from_bits(bits)
    cum = np.concatenate([[0], np.cumsum(bits)])
    for pos in [0, 1, 7, 8, 511, 512, 9999, 10_000]:
        assert bv.rank1(pos) == cum[pos]
    ones = np.flatnonzero(bits)
    zeros = np.flatnonzero(1 - bits)
    for j in [0, 5, len(ones) - 1]:
        assert bv.select1(j) == ones[j]
    for j in [0, 5, len(zeros) - 1]:
        assert bv.select0(j) == zeros[j]


def test_pack_unpack_lowbits():
    rng = np.random.default_rng(17)
    vals = rng.integers(0, 1 << 9, size=100)
    packed = pack_lowbits(vals, 9)
    np.testing.assert_array_equal(unpack_lowbits(packed, 9, 100), vals)
    np.testing.assert_array_equal(unpack_lowbits(packed, 9, 100, 10, 5), vals[10:15])


@pytest.mark.parametrize("p", [0.02, 0.3, 0.5, 0.9])
def test_rrr_rank_select(p):
    rng = np.random.default_rng(18)
    bits = (rng.random(4000) < p).astype(np.uint8)
    rv = RRRVector.from_bits(bits)
    cum = np.concatenate([[0], np.cumsum(bits)])
    for pos in [0, 1, 30, 31, 32, 495, 496, 3999, 4000]:
        assert rv.rank1(pos) == cum[pos], pos
    ones = np.flatnonzero(bits)
    zeros = np.flatnonzero(1 - bits)
    for j in [0, len(ones) // 2, len(ones) - 1]:
        assert rv.select1(j) == ones[j]
    for j in [0, len(zeros) // 2, len(zeros) - 1]:
        assert rv.select0(j) == zeros[j]
    np.testing.assert_array_equal(rv.bits(), bits)


def test_rrr_compresses_skewed_bits():
    rng = np.random.default_rng(19)
    bits = (rng.random(100_000) < 0.05).astype(np.uint8)
    rv = RRRVector.from_bits(bits)
    assert rv.size_bits < 0.55 * len(bits)  # H(0.05)~0.29 + class overhead


# ---------------------------------------------------------------------------
# Wavelet tree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("K", [4, 7, 16])
def test_wavelet_tree_select_access(K, compressed):
    rng = np.random.default_rng(20)
    N = 2000
    s = rng.integers(0, K, size=N)
    wt = WaveletTree.build(s, K, compressed=compressed)
    for k in range(K):
        ids = np.flatnonzero(s == k)
        assert wt.cluster_size(k) == len(ids)
        for o in [0, len(ids) // 2, len(ids) - 1]:
            if o >= 0 and len(ids):
                assert wt.select(k, o) == ids[o]
    for i in [0, 1, N // 2, N - 1]:
        assert wt.access(i) == s[i]


def test_wavelet_tree_decode_cluster():
    rng = np.random.default_rng(21)
    s = rng.integers(0, 8, size=500)
    wt = WaveletTree.build(s, 8)
    for k in range(8):
        np.testing.assert_array_equal(wt.decode_cluster(k), np.flatnonzero(s == k))


def test_wavelet_tree_rate():
    # flat WT payload = N * ceil(log2 K) exactly
    rng = np.random.default_rng(22)
    s = rng.integers(0, 1024, size=5000)
    wt = WaveletTree.build(s, 1024)
    assert wt.size_bits == 5000 * 10


# ---------------------------------------------------------------------------
# REC
# ---------------------------------------------------------------------------

def _random_graph(rng, n, deg):
    edges = set()
    while len(edges) < n * deg:
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        if u != v:
            edges.add((u, v))
    return np.array(sorted(edges), dtype=np.int64)


@pytest.mark.parametrize("model", ["polya", "degree"])
def test_rec_roundtrip(model):
    rng = np.random.default_rng(23)
    edges = _random_graph(rng, 60, 4)
    res = rec_encode(edges, 60, model=model)
    out = rec_decode(res, 60, edges.shape[0])
    np.testing.assert_array_equal(out, edges)


def test_rec_saves_edge_order_bits():
    """REC should land near 2E log N - log E! for a uniform-ish graph."""
    import math

    rng = np.random.default_rng(24)
    n, deg = 256, 8
    edges = _random_graph(rng, n, deg)
    E = edges.shape[0]
    res = rec_encode(edges, n, model="polya")
    naive = E * 2 * math.log2(n)
    saving = math.lgamma(E + 1) / math.log(2)
    # the urn model also pays for degree learning; allow slack
    assert res.payload_bits < naive - 0.5 * saving


# ---------------------------------------------------------------------------
# Polya PQ-code codec
# ---------------------------------------------------------------------------

def test_polya_roundtrip():
    rng = np.random.default_rng(25)
    sizes = [37, 100, 1, 64]
    m = 4
    clusters = [rng.integers(0, 256, size=(n, m)).astype(np.uint8) for n in sizes]
    heads, words, bits = polya_encode_clusters(clusters)
    out = polya_decode_clusters(heads, words, sizes, m)
    for a, b in zip(out, clusters):
        np.testing.assert_array_equal(a, b)


def test_polya_compresses_skewed_codes():
    rng = np.random.default_rng(26)
    # codes concentrated on few symbols within each cluster -> low entropy
    sizes = [512] * 8
    m = 8
    clusters = [
        (rng.integers(0, 8, size=(n, m)) * 3 + rng.integers(0, 3, size=(n, m)))
        .astype(np.uint8)
        for n in sizes
    ]
    _, _, bits = polya_encode_clusters(clusters)
    bpe = bits / (sum(sizes) * m)
    assert bpe < 6.0  # true entropy ~log2(24)=4.6 + adaptation cost


def test_polya_random_codes_near_8_bits():
    rng = np.random.default_rng(27)
    sizes = [1024] * 4
    clusters = [rng.integers(0, 256, size=(n, 4)).astype(np.uint8) for n in sizes]
    _, _, bits = polya_encode_clusters(clusters)
    bpe = bits / (sum(sizes) * 4)
    assert 7.9 < bpe < 8.6  # incompressible codes stay ~8 bits


# ---------------------------------------------------------------------------
# webgraph-lite (Zuckerli stand-in)
# ---------------------------------------------------------------------------

def test_webgraph_roundtrip():
    rng = np.random.default_rng(28)
    n = 80
    adj = [
        np.unique(rng.integers(0, n, size=rng.integers(1, 12)))
        for _ in range(n)
    ]
    ans = webgraph_encode(adj, n)
    out = webgraph_decode(ans, n, n)
    for a, b in zip(out, adj):
        np.testing.assert_array_equal(a, np.sort(b))


def test_webgraph_exploits_overlap():
    # identical consecutive lists should compress far below gap coding
    base = np.array([3, 17, 40, 41, 42, 99, 150, 151], dtype=np.int64)
    adj = [base for _ in range(50)]
    ans = webgraph_encode(adj, 200)
    bits_per_edge = ans.bits / (50 * len(base))
    assert bits_per_edge < 4.0
