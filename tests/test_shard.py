"""Sharded serving subsystem: planner, scatter/merge parity, faults.

The load-bearing claim (ISSUE acceptance): ``ShardedAnnService.search``
over a plan's shards is **bit-identical** — ids AND distances — to
searching the unsharded index, for every id codec and engine, as long as
no faults are injected.  Plus graceful degradation: a dead/slow shard
yields partial results (``stats.partial=True``), never an exception.
"""

import numpy as np
import pytest

from repro.api import index_factory
from repro.serve import AnnService, BatchPolicy
from repro.shard import (RetryPolicy, ScriptedFaults, ShardedAnnService,
                         ShardPlan, plan_shards)

K = 12
NPROBE = 5


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((1500, 16)).astype(np.float32)
    # duplicate vectors -> exact distance ties; the merge must reproduce
    # the monolithic tie order, not just the distances
    x[200] = x[100]
    x[201] = x[100]
    q = rng.standard_normal((9, 16)).astype(np.float32)
    return x, q


def _mono(data, spec, **build_kw):
    x, _ = data
    return index_factory(spec).build(x, seed=0, **build_kw)


# ---------------------------------------------------------------------------
# parity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ids", ["roc", "wt", "gap_ans"])
@pytest.mark.parametrize("nshards", [1, 2, 5])
def test_ivf_shard_parity_matrix(data, ids, nshards):
    x, q = data
    mono = _mono(data, f"IVF32,ids={ids}")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE)
    plan = plan_shards(mono, nshards)
    svc = ShardedAnnService(plan, topk=K, nprobe=NPROBE)
    ids_s, d_s, st = svc.search(q, with_stats=True)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)
    assert st.partial is False and st.shards == nshards
    assert st.shards_failed == 0


@pytest.mark.parametrize("by", ["range", "hash"])
def test_ivf_shard_parity_schemes(data, by):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE)
    plan = plan_shards(mono, 3, by=by)
    svc = ShardedAnnService(plan, topk=K, nprobe=NPROBE)
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


def test_ivf_uneven_shards_and_k_over_shard_capacity(data):
    """Pathological split: one shard owns 2 clusters (often fewer than k
    candidates under the probe set), another owns 28."""
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE)
    plan = plan_shards(mono, 3, by="range", boundaries=[0, 2, 30, 32])
    assert [s.clusters for s in plan.shards] == [[0, 2], [2, 30], [30, 32]]
    svc = ShardedAnnService(plan, topk=K, nprobe=NPROBE)
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


def test_ivf_shard_parity_pallas_engine(data):
    x, q = data
    mono = index_factory("IVF16,ids=roc").build(x[:400], seed=0)
    d0, i0, _ = mono.search(q[:4], k=8, nprobe=4, engine="pallas")
    svc = ShardedAnnService(plan_shards(mono, 2), topk=8,
                            nprobe=4, engine="pallas")
    ids_s, d_s = svc.search(q[:4])
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


@pytest.mark.parametrize("engine", ["xla", "pallas"])
def test_ivf_shard_parity_device_select(data, engine):
    """Sharded merge over device-selected shards: the merge keys consume
    device-chosen offsets unchanged, so the merged output stays
    bit-identical to the unsharded device-select call — and to the
    unsharded host-select call."""
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE, engine=engine,
                            select="host")
    d1, i1, _ = mono.search(q, k=K, nprobe=NPROBE, engine=engine,
                            select="device")
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_array_equal(d1, d0)
    svc = ShardedAnnService(plan_shards(mono, 3), topk=K, nprobe=NPROBE,
                            engine=engine, select="device")
    ids_s, d_s, st = svc.search(q, with_stats=True)
    stats = svc.stats()
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)
    # per-shard device_select counters survive combine_stats and the
    # service ledger: the host never received a (qb, C_pad) block
    assert st.device_select > 0 and st.host_block_bytes > 0
    assert stats["device_selects"] > 0


def test_graph_shard_parity_device_select():
    """Graph shards under device select, in the exhaustive regime
    (ef >= n) where sharded graph parity is exact."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((300, 8)).astype(np.float32)
    q = rng.standard_normal((5, 8)).astype(np.float32)
    mono = index_factory("NSG8,ids=roc").build(x, seed=0)
    d0, i0, _ = mono.search(q, k=10, ef=400, select="host")
    d1, i1, _ = mono.search(q, k=10, ef=400, select="device")
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_array_equal(d1, d0)
    svc = ShardedAnnService(plan_shards(mono, 2, seed=0), topk=10, ef=400,
                            select="device")
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


def test_ivf_shard_parity_pq_polya(data):
    x, q = data
    mono = _mono(data, "IVF32,PQ4,ids=gap_ans,codes=polya")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE)
    svc = ShardedAnnService(plan_shards(mono, 2), topk=K, nprobe=NPROBE)
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


def test_flat_shard_parity(data):
    x, q = data
    mono = index_factory("Flat").build(x)
    d0, i0, _ = mono.search(q, k=K)
    svc = ShardedAnnService(plan_shards(mono, 3), topk=K)
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


@pytest.mark.parametrize("nshards", [1, 2])
def test_nsg_shard_parity_exhaustive(nshards):
    """Graph shards are rebuilt subgraphs, so parity holds in the
    exhaustive regime (ef >= n): every shard then returns its true
    per-partition top-k and the merge equals exact search."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((300, 8)).astype(np.float32)
    q = rng.standard_normal((5, 8)).astype(np.float32)
    mono = index_factory("NSG8,ids=roc").build(x, seed=0)
    d0, i0, _ = mono.search(q, k=10, ef=400)
    svc = ShardedAnnService(plan_shards(mono, nshards, seed=0),
                            topk=10, ef=400)
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


# ---------------------------------------------------------------------------
# manifest + artifacts
# ---------------------------------------------------------------------------

def test_plan_save_load_roundtrip(tmp_path, data):
    x, q = data
    mono = _mono(data, "IVF32,ids=wt")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE)
    plan = plan_shards(mono, 3)
    mpath = plan.save(tmp_path)
    assert mpath.name == "shards.json"
    loaded = ShardPlan.load(tmp_path)
    assert loaded.source_spec == "IVF32,ids=wt"
    assert loaded.nshards == 3 and loaded.n == len(x)
    # per-shard id_bits bookkeeping must round-trip (wt sentinel rule)
    for a, b in zip(plan.indexes, loaded.indexes):
        assert a.ivf.id_bits() == b.ivf.id_bits()
    svc = ShardedAnnService(loaded, topk=K, nprobe=NPROBE)
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


def test_manifest_contents(data):
    x, _ = data
    mono = _mono(data, "IVF32,ids=roc")
    plan = plan_shards(mono, 4)
    m = plan.manifest()
    assert m["format"] == "ridx-shards" and m["kind"] == "ivf"
    assert m["by"] == "range" and m["nshards"] == 4
    assert sum(s["n_local"] for s in m["shards"]) == len(x)
    for s in m["shards"]:
        assert s["spec"] == "IVF32,ids=roc"
        assert s["ledger"]["total_bytes"] > 0
        assert s["ledger"]["ids_bytes"] > 0
        lo, hi = s["clusters"]
        assert 0 <= lo <= hi <= 32
    # shards partition the id universe
    seen = np.zeros(len(x), bool)
    for idx in plan.indexes:
        held = np.concatenate([l for l in idx.ivf._lists if len(l)])
        assert not seen[held].any()
        seen[held] = True
    assert seen.all()


def test_plan_validation(data):
    mono = _mono(data, "IVF32,ids=roc")
    with pytest.raises(ValueError):
        plan_shards(mono, 0)
    with pytest.raises(ValueError):
        plan_shards(mono, 2, by="range", boundaries=[0, 40, 32])
    with pytest.raises(ValueError):
        plan_shards(mono, 2, by="zone")
    with pytest.raises(ValueError):
        plan_shards(mono, 2, assignments=np.zeros(7, np.int64))
    # shard indexes are frozen id universes: add() must refuse
    x, _ = data
    flat = index_factory("Flat").build(x)
    shard = plan_shards(flat, 2).indexes[0]
    with pytest.raises(ValueError):
        shard.add(x[:3])


def test_custom_assignments(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE)
    rng = np.random.default_rng(0)
    owner = rng.integers(0, 3, size=32)
    plan = plan_shards(mono, 3, assignments=owner)
    assert plan.by == "custom"
    svc = ShardedAnnService(plan, topk=K, nprobe=NPROBE)
    ids_s, d_s = svc.search(q)
    svc.close()
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


# ---------------------------------------------------------------------------
# faults + degraded mode
# ---------------------------------------------------------------------------

def test_dead_shard_degrades_to_partial(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    plan = plan_shards(mono, 3)
    svc = ShardedAnnService(plan, topk=K, nprobe=NPROBE,
                            fault_policy=ScriptedFaults(dead=[1]),
                            retry=RetryPolicy(sleep=lambda s: None))
    ids_s, d_s, st = svc.search(q, with_stats=True)  # must not raise
    svc.close()
    assert st.partial is True
    assert st.shards_failed == 1 and st.shards == 3
    # survivors still answer: results are the merge of shards 0 and 2
    assert np.isfinite(d_s[:, 0]).all()
    dead_ids = np.concatenate(
        [l for l in plan.indexes[1].ivf._lists if len(l)])
    assert not np.isin(ids_s[np.isfinite(d_s)], dead_ids).any()
    assert svc.stats()["partial_batches"] == 1.0
    assert svc.stats()["shards_failed"] == 1.0


def test_all_shards_dead_still_no_crash(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    svc = ShardedAnnService(plan_shards(mono, 2), topk=K, nprobe=NPROBE,
                            fault_policy=ScriptedFaults(dead=[0, 1]),
                            retry=RetryPolicy(sleep=lambda s: None))
    ids_s, d_s, st = svc.search(q, with_stats=True)
    svc.close()
    assert st.partial is True and st.shards_failed == 2
    assert np.isinf(d_s).all() and (ids_s == 0).all()


def test_flaky_shard_retry_recovers_full_results(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    d0, i0, _ = mono.search(q, k=K, nprobe=NPROBE)
    svc = ShardedAnnService(
        plan_shards(mono, 3), topk=K, nprobe=NPROBE,
        fault_policy=ScriptedFaults(flaky={0: 1, 2: 1}),
        retry=RetryPolicy(max_attempts=3, sleep=lambda s: None))
    ids_s, d_s, st = svc.search(q, with_stats=True)
    svc.close()
    assert st.partial is False and st.shards_failed == 0
    assert st.retries == 2
    np.testing.assert_array_equal(ids_s, i0)
    np.testing.assert_array_equal(d_s, d0)


def test_retries_exhausted_degrades(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    svc = ShardedAnnService(
        plan_shards(mono, 2), topk=K, nprobe=NPROBE,
        fault_policy=ScriptedFaults(flaky={0: 99}),
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None))
    _, _, st = svc.search(q, with_stats=True)
    svc.close()
    assert st.partial is True and st.shards_failed == 1
    assert len(svc.fault_log) == 1


def test_deadline_drops_slow_shard(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    svc = ShardedAnnService(
        plan_shards(mono, 3), topk=K, nprobe=NPROBE, deadline_s=0.05,
        fault_policy=ScriptedFaults(delay_s={2: 1.0}),
        retry=RetryPolicy(max_attempts=1))
    _, _, st = svc.search(q, with_stats=True)
    svc.close()
    assert st.partial is True and st.shards_failed == 1


# ---------------------------------------------------------------------------
# cache budget + stats surface
# ---------------------------------------------------------------------------

def test_cache_budget_split_across_shards(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    budget_mb = 1.0
    svc = ShardedAnnService(plan_shards(mono, 4), topk=K, nprobe=NPROBE,
                            cache_mb=budget_mb)
    for w in svc._workers:
        assert w.index.ivf.decoded_cache.max_bytes == int(
            budget_mb / 4 * (1 << 20))
    for _ in range(3):
        svc.search(q)
    led = svc.memory_ledger()
    svc.close()
    assert led["shards"] == 4.0
    # aggregate decoded-cache residency respects the global budget
    assert 0 < led["decoded_cache_bytes"] <= budget_mb * (1 << 20)
    # aggregate compressed ids beat the compact baseline like the mono index
    assert led["ids_bytes"] < led["ids_bytes_compact"] < led["ids_bytes_unc64"]


def test_sharded_stats_and_latency_keys(data):
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    svc = ShardedAnnService(plan_shards(mono, 2), topk=K, nprobe=NPROBE)
    for i in range(4):
        svc.search(q[i:i + 2])
    st = svc.stats()
    svc.close()
    assert st["requests"] == 4 and st["queries"] == 8
    assert st["batches"] == 4 and st["shards"] == 2.0
    assert st["partial_batches"] == 0.0 and st["retries"] == 0.0
    assert 0.0 < st["p50_latency_s"] <= st["p95_latency_s"]
    assert st["mean_latency_s"] > 0.0 and st["merge_s"] > 0.0
    ws = svc.worker_stats()
    assert len(ws) == 2 and all(w["batches"] == 4 for w in ws)


def test_ann_service_latency_percentiles(data):
    """Satellite: per-ticket submit->flush latency percentiles on the
    monolithic AnnService, deterministic via the injectable clock."""
    x, q = data
    mono = _mono(data, "IVF32,ids=roc")
    t = [0.0]

    def clock():
        t[0] += 0.010
        return t[0]

    svc = AnnService(mono, topk=5, nprobe=2, clock=clock,
                     policy=BatchPolicy(max_batch=10**9,
                                        max_wait_s=float("inf")))
    for i in range(5):
        svc.submit(q[i:i + 1])
    svc.flush()
    st = svc.stats()
    # clock ticks 10ms per call: submit i enqueues at tick i+1 (plus one
    # tick() probe each), flush reads start/done ticks after the last
    assert st["p50_latency_s"] > 0.0
    assert st["p95_latency_s"] >= st["p50_latency_s"] >= 0.0
    assert st["mean_latency_s"] >= st["mean_wait_s"]
    for key in ("p50_latency_s", "p95_latency_s", "mean_latency_s"):
        assert key in svc.stats.__doc__  # documented stat keys
