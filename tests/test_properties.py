"""System-invariant property tests (hypothesis) across the stack."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional (tests/requirements-test.txt): without it the
    from hypothesis import given, settings, strategies as st
except ImportError:  # properties run over deterministic seeded samples
    from _compat_hypothesis import given, settings, st

from repro.core.ans import StreamANS
from repro.core.elias_fano import EliasFano
from repro.core.polya import polya_decode_clusters, polya_encode_clusters
from repro.core.wavelet_tree import WaveletTree

jax.config.update("jax_platforms", "cpu")


# ---------------------------------------------------------------------------
# coders
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31), st.integers(1, 500))
@settings(max_examples=30, deadline=None)
def test_streamans_random_op_sequences(seed, n_ops):
    """Any pow2-total op sequence round-trips and restores the seed state."""
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        r = int(rng.integers(1, 17))
        f = int(rng.integers(1, (1 << r) + 1))
        c = int(rng.integers(0, (1 << r) - f + 1))
        ops.append((c, f, r))
    ans = StreamANS()
    for c, f, r in ops:
        ans.push(c, f, r)
    for c, f, r in reversed(ops):
        if f == (1 << r):
            continue
        cf = ans.pop_cf(r)
        assert c <= cf < c + f
        ans.pop_advance(c, f, r)
    assert ans.head == 1 << 32 and not ans.tail


@given(st.integers(0, 2**31), st.integers(1, 200), st.integers(2, 12))
@settings(max_examples=25, deadline=None)
def test_ef_monotone_roundtrip_and_access(seed, n, logu):
    rng = np.random.default_rng(seed)
    universe = max(n + 1, 1 << logu)
    ids = np.sort(rng.choice(universe, size=min(n, universe - 1), replace=False))
    ef = EliasFano.encode(ids, universe)
    np.testing.assert_array_equal(ef.decode(), ids)
    i = int(rng.integers(0, len(ids)))
    assert ef.access(i) == ids[i]


@given(st.integers(0, 2**31), st.integers(2, 20), st.integers(10, 400))
@settings(max_examples=20, deadline=None)
def test_wavelet_tree_select_inverts_access(seed, K, N):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, K, size=N)
    wt = WaveletTree.build(s, K)
    k = int(rng.integers(0, K))
    occs = np.flatnonzero(s == k)
    for o in range(min(3, len(occs))):
        pos = wt.select(k, o)
        assert s[pos] == k and pos == occs[o]
        assert wt.access(pos) == k


@given(st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_polya_arbitrary_cluster_shapes(seed):
    rng = np.random.default_rng(seed)
    C = int(rng.integers(1, 6))
    m = int(rng.integers(1, 5))
    sizes = [int(rng.integers(1, 80)) for _ in range(C)]
    clusters = [rng.integers(0, 256, size=(n, m)).astype(np.uint8)
                for n in sizes]
    heads, words, bits = polya_encode_clusters(clusters)
    out = polya_decode_clusters(heads, words, sizes, m)
    for a, b in zip(out, clusters):
        np.testing.assert_array_equal(a, b)
    assert bits > 0


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_and_conservation(seed):
    """No expert processes more than C tokens; gates renormalize to <= 1."""
    from repro.configs import get_config, reduced
    from repro.models.moe import init_moe, moe_apply, moe_capacity

    rng = np.random.default_rng(seed)
    cfg = reduced(get_config("olmoe-1b-7b"))
    params = init_moe(jax.random.PRNGKey(seed % 1000), cfg)
    B, S = 2, int(rng.integers(8, 33))
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0.0
    # capacity: dispatch buffer is (E, C, d) with C bounded
    C = moe_capacity(B * S, cfg)
    assert C <= B * S


# ---------------------------------------------------------------------------
# sharding rules: any parameter tree gets valid, divisible specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-72b", "llama4-scout-17b-a16e",
                                  "zamba2-2.7b", "whisper-medium"])
def test_param_specs_always_divisible_full_configs(arch):
    from repro.configs import get_config
    from repro.distributed.sharding import param_spec
    from repro.models import build

    cfg = get_config(arch)
    tree = jax.eval_shape(build(cfg).init, jax.random.PRNGKey(0))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = param_spec(name, leaf.shape, FakeMesh(), cfg.n_experts)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([FakeMesh.shape[a] for a in axes]))
            assert dim % size == 0, (name, leaf.shape, spec)


# ---------------------------------------------------------------------------
# checkpoint: arbitrary pytrees
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31), st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_checkpoint_arbitrary_trees(seed, depth):
    import tempfile

    from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint

    rng = np.random.default_rng(seed)

    def make(d):
        if d == 0:
            shape = tuple(int(x) for x in rng.integers(1, 5, rng.integers(1, 3)))
            return jnp.asarray(rng.standard_normal(shape))
        return {f"k{i}": make(d - 1) for i in range(int(rng.integers(1, 3)))}

    tree = make(depth % 3 + 1)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
