"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property-test modules guard their import with::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _compat_hypothesis import given, settings, st

With real hypothesis absent, ``@given`` degrades to a
``pytest.mark.parametrize`` over a fixed number of deterministic samples
drawn with a seeded generator from the same strategy bounds — the
roundtrip properties still execute (over fewer, reproducible cases)
instead of the whole module failing at collection.

Only the strategy surface those modules use is implemented:
``st.integers(min, max)`` and ``st.lists(st.integers(...), min_size,
max_size)``.
"""

from __future__ import annotations

import numpy as np
import pytest

_N_CASES = 5
_SEED = 0xC0DEC5


class _Integers:
    def __init__(self, min_value: int, max_value: int):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def sample(self, rng: np.random.Generator, edge: bool):
        if edge:  # first case pins the bounds
            return self.min_value
        return int(rng.integers(self.min_value, self.max_value + 1))


class _Lists:
    def __init__(self, elements: _Integers, min_size: int = 0,
                 max_size: int = 10):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size)

    def sample(self, rng: np.random.Generator, edge: bool):
        size = (self.min_size if edge
                else int(rng.integers(self.min_size, self.max_size + 1)))
        return [self.elements.sample(rng, False) for _ in range(size)]


class st:  # noqa: N801 - mirrors `hypothesis.strategies` usage
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elements: _Integers, min_size: int = 0,
              max_size: int = 10) -> _Lists:
        return _Lists(elements, min_size=min_size, max_size=max_size)


def settings(**_kwargs):
    """No-op stand-in for ``hypothesis.settings``."""

    def deco(fn):
        return fn

    return deco


def given(*strategies):
    """Parametrize over deterministic samples of the given strategies."""

    def deco(fn):
        rng = np.random.default_rng(_SEED)
        cases = [
            tuple(s.sample(rng, edge=(i == 0)) for s in strategies)
            for i in range(_N_CASES)
        ]

        # NOTE: no functools.wraps — pytest would follow __wrapped__ to the
        # original signature and treat the strategy args as fixtures.
        @pytest.mark.parametrize("_compat_case", cases,
                                 ids=[f"case{i}" for i in range(len(cases))])
        def wrapper(_compat_case):
            return fn(*_compat_case)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
