"""The public examples run end-to-end on tiny data (tier-1 fast suite).

Each example is executed as a real subprocess (fresh interpreter, its own
``PYTHONPATH=src``) so the *documented* entry points — not just the
library internals — are exercised by every default test run.
"""

import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _run(script: str, *args: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / script), *args],
        capture_output=True, text=True, timeout=420, env=env, cwd=str(ROOT))
    assert proc.returncode == 0, (
        f"{script} failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


def test_quickstart_end_to_end():
    out = _run("quickstart.py", "--n", "3000", "--queries", "30",
               "--nlist", "32", "--graph-n", "400")
    assert "compression is lossless" in out
    assert "bit-identical results" in out
    assert "same search API" in out


def test_serve_ann_end_to_end():
    out = _run("serve_ann.py", "--n", "3000", "--queries", "60",
               "--nlist", "32", "--pq-m", "8", "--engine", "xla",
               "--cache-mb", "4")
    assert "recall@10" in out
    assert "RAM ledger" in out


def test_serve_ann_graph_spec():
    out = _run("serve_ann.py", "--n", "1200", "--queries", "40",
               "--spec", "NSG8,ids=roc", "--request-size", "2")
    assert "recall@10" in out
    assert "b/edge" in out
