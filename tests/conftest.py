"""Shared test configuration.

* Auto-applies the ``slow`` marker (see pytest.ini) to the JAX model
  modules — per-arch smoke tests, train/serve drivers, and the
  multi-device subprocess suite — so the default run stays fast.
  Individual tests elsewhere can still opt in with ``@pytest.mark.slow``.
"""

import pytest

# NOTE: test_distributed is not listed — in-process it self-skips (single
# device) and the test_multidevice subprocess (which IS slow-marked) must
# still select it despite the default `-m "not slow"` addopts.
SLOW_MODULES = {
    "test_arch_smoke",
    "test_checkpoint_train",
    "test_multidevice",
}


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.module.__name__ in SLOW_MODULES:
            item.add_marker(pytest.mark.slow)
