"""Train an LM embedder, then mount a compressed retrieval index on it.

The full pipeline a kNN-LM / RAG deployment runs:
  1. train a decoder LM for a few hundred steps (CPU-sized by default;
     --full trains the ~100M-param config — same code path, TPU-sized),
  2. embed a corpus with the trained model,
  3. build a RetrievalIndex with ROC-compressed ids (the paper's feature),
  4. serve queries and report recall + the id-compression ledger.

    PYTHONPATH=src python examples/train_embedder.py [--steps 200] [--full]
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import TokenPipeline
from repro.launch.train import main as train_main
from repro.models import build
from repro.retrieval.index import RetrievalIndex, embed_corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (TPU-sized; slow on CPU)")
    ap.add_argument("--docs", type=int, default=5_000)
    args = ap.parse_args()

    cfg = get_config("gemma3-1b")
    if args.full:
        cfg = dataclasses.replace(cfg, n_layers=12, d_model=768, n_heads=4,
                                  n_kv_heads=1, head_dim=192, d_ff=3072,
                                  vocab_size=32_768, vocab_pad_to=1,
                                  sliding_window=256, dtype="float32")
    else:
        cfg = reduced(cfg)

    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(
            jax.eval_shape(build(cfg).init, jax.random.PRNGKey(0))))
    print(f"[1/4] training {cfg.name} ({n_params/1e6:.1f}M params) "
          f"for {args.steps} steps...")
    train_args = ["--arch", "gemma3-1b", "--steps", str(args.steps),
                  "--batch", "4", "--seq", "64", "--lr", "1e-3"]
    if not args.full:
        train_args.append("--reduced")
    train_main(train_args)

    # re-init a model of the trained shape for embedding (train_main keeps
    # its weights internal; the index mechanics are the point here, not
    # embedding quality)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(7))

    print(f"[2/4] embedding {args.docs} documents...")
    pipe = TokenPipeline(vocab=cfg.vocab_size, batch=32, seq_len=64, seed=9)
    batches = [pipe.batch_at(i)["tokens"] for i in range(args.docs // 32)]
    emb = embed_corpus(cfg, params, batches)
    print(f"  embeddings: {emb.shape}")

    print("[3/4] building RetrievalIndex (factory spec: IVF64,ids=roc)...")
    ri = RetrievalIndex(spec="IVF64,ids=roc").build(emb)
    stats = ri.stats()
    print(f"  ids: {stats['bits_per_id']:.2f} bits/id "
          f"(compact would be {stats['compact_bits']:.0f})")

    print("[4/4] querying...")
    qids, _, st = ri.search(emb[:64], nprobe=8, topk=5)
    self_recall = np.mean(qids[:, 0] == np.arange(64))
    print(f"  self-recall@1: {self_recall:.2f} "
          f"({st.wall_s/64*1e3:.2f} ms/query)")


if __name__ == "__main__":
    main()
