"""Quickstart: compress the ids of an IVF index, losslessly.

Builds a 100k-vector IVF index, stores its inverted-list ids through each
codec, verifies search results are bit-identical, and prints the paper's
Table-1-style comparison.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.ann.ivf import IVFIndex
from repro.data.synthetic import make_dataset


def main():
    print("building dataset (100k x 96)...")
    base, queries = make_dataset("deep-like", 100_000, 100, seed=0)

    ref = None
    print(f"\n{'codec':>10} {'bits/id':>8} {'vs compact':>10} {'search ms':>10} "
          f"{'identical':>9}")
    for codec in ["unc64", "compact", "ef", "roc", "gap_ans", "wt", "wt1"]:
        idx = IVFIndex(nlist=256, id_codec=codec).build(base, seed=1)
        ids, _, st = idx.search(queries, nprobe=8, topk=10)
        if ref is None:
            ref = ids
        same = bool(np.array_equal(ids, ref))
        compact = np.ceil(np.log2(len(base)))
        print(f"{codec:>10} {idx.bits_per_id():8.2f} "
              f"{idx.bits_per_id()/compact:9.1%} "
              f"{st.wall_s/len(queries)*1e3:10.3f} {str(same):>9}")
    print("\nAll codecs return identical results — compression is lossless.")


if __name__ == "__main__":
    main()
