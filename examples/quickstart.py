"""Quickstart: one factory string per index, lossless ids, save/load.

Builds IVF indexes through ``repro.api.index_factory`` — one spec string
selects the structure, the id codec and the payload coding — verifies
search results are bit-identical across codecs, round-trips one index
through the RIDX v2 container (``save_index``/``load_index``), and
serves a graph index through the same API.

    PYTHONPATH=src python examples/quickstart.py [--n 100000] [--queries 100]
"""

import argparse

import numpy as np

from repro.api import index_factory, load_index, save_index
from repro.data.synthetic import make_dataset


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--nlist", type=int, default=256)
    ap.add_argument("--graph-n", type=int, default=0,
                    help="also build an NSG index on this many points "
                         "(0 = skip; O(n^2) build)")
    args = ap.parse_args(argv)

    print(f"building dataset ({args.n} x 96)...")
    base, queries = make_dataset("deep-like", args.n, args.queries, seed=0)

    # -- one spec string per row of the paper's Table 1 ---------------------
    ref = None
    print(f"\n{'spec':>34} {'bits/id':>8} {'vs compact':>10} {'search ms':>10} "
          f"{'identical':>9}")
    for codec in ["unc64", "compact", "ef", "roc", "gap_ans", "wt", "wt1"]:
        spec = f"IVF{args.nlist},ids={codec}"
        idx = index_factory(spec).build(base, seed=1)
        dists, ids, st = idx.search(queries, k=10, nprobe=8)
        if ref is None:
            ref = ids
        same = bool(np.array_equal(ids, ref))
        compact = np.ceil(np.log2(len(base)))
        bpe = idx.ivf.bits_per_id()
        print(f"{spec:>34} {bpe:8.2f} {bpe/compact:9.1%} "
              f"{st.wall_s/len(queries)*1e3:10.3f} {str(same):>9}")
    print("\nAll codecs return identical results — compression is lossless.")

    # -- save/load: the RIDX v2 container round-trips losslessly ------------
    spec = f"IVF{args.nlist},PQ8x8,ids=roc,codes=polya"
    idx = index_factory(spec).build(base, seed=1)
    d0, i0, _ = idx.search(queries, k=10)
    blob = save_index(idx)
    idx2 = load_index(blob)
    d1, i1, _ = idx2.search(queries, k=10)
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)
    led = idx.memory_ledger()
    print(f"\nsave/load ({spec}):")
    print(f"  container: {len(blob)/1e6:.2f} MB on disk "
          f"(ids+codes in RAM: {(led['ids_bytes']+led['payload_bytes'])/1e6:.2f} MB, "
          f"uncompressed: {(led['ids_bytes_unc64']+led['payload_bytes_unc'])/1e6:.2f} MB)")
    print("  reloaded index returns bit-identical results.")

    # -- the same front door serves graph indexes ---------------------------
    if args.graph_n:
        gbase = base[: args.graph_n]
        gidx = index_factory("NSG16,ids=roc").build(gbase, seed=1)
        gd, gi, gst = gidx.search(queries, k=10, ef=32)
        blob = save_index(gidx)          # friend lists via webgraph-lite
        gidx2 = load_index(blob)
        gd2, gi2, _ = gidx2.search(queries, k=10, ef=32)
        assert np.array_equal(gi, gi2) and np.array_equal(gd, gd2)
        print(f"\nNSG16,ids=roc on {args.graph_n} pts: "
              f"{gidx.graph.bits_per_edge():.2f} bits/edge, "
              f"{gst.visited} nodes visited, container {len(blob)/1e3:.0f} KB "
              "— same search API, bit-identical after reload.")


if __name__ == "__main__":
    main()
