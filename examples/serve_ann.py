"""End-to-end driver: serve any factory-built ANN index (batched).

The paper's deployment scenario: a RAM-resident index answers
nearest-neighbor requests; vector ids are losslessly compressed and id
resolution is deferred to the final top-k (§4.1).  The index is built
from one ``--spec`` factory string (IVF, NSG/HNSW or Flat) and requests
stream through :class:`repro.serve.AnnService`, which micro-batches them
(max-batch/max-wait policy) into the index's search engine.  Reports
recall@10 vs exact search, QPS, batching and decode stats, and the RAM
ledger vs the uncompressed layout.

    PYTHONPATH=src python examples/serve_ann.py [--n 200000] [--queries 2000]
    PYTHONPATH=src python examples/serve_ann.py --spec "IVF512,ids=ef" --cache-mb 16

With ``--shards N`` the built index is split by the shard planner and
served through :class:`repro.shard.ShardedAnnService` (scatter/merge,
bit-identical to the monolithic service when healthy); ``--fault-rate p``
injects seeded random per-shard failures to demo degraded mode:

    PYTHONPATH=src python examples/serve_ann.py --shards 4 --fault-rate 0.05
"""

import argparse
import time

import numpy as np

from repro.api import index_factory
from repro.data.synthetic import make_dataset
from repro.serve import AnnService, BatchPolicy


def exact_topk(base, queries, k):
    out = np.zeros((len(queries), k), np.int64)
    for i in range(0, len(queries), 256):
        q = queries[i:i + 256]
        d = (np.sum(q**2, 1, keepdims=True) - 2 * q @ base.T
             + np.sum(base**2, 1)[None])
        out[i:i + 256] = np.argsort(d, 1)[:, :k]
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=1_000)
    ap.add_argument("--spec", default=None,
                    help="factory spec (default: IVF<nlist>,PQ<pq-m>x8,"
                         "ids=roc,codes=polya); e.g. 'NSG16,ids=roc'")
    ap.add_argument("--nlist", type=int, default=1024)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--ef", type=int, default=32,
                    help="beam width for graph specs")
    ap.add_argument("--pq-m", type=int, default=8)
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="decoded-list cache budget (MB); default 64")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--request-size", type=int, default=4,
                    help="queries per client request")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "pallas", "xla"])
    ap.add_argument("--shards", type=int, default=0,
                    help="split the index and serve via ShardedAnnService")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="seeded random per-shard failure probability "
                         "(needs --shards)")
    args = ap.parse_args(argv)
    if args.fault_rate and not args.shards:
        ap.error("--fault-rate needs --shards")

    print(f"dataset: {args.n} x 128 (sift-like)")
    base, queries = make_dataset("sift-like", args.n, args.queries, seed=0)
    gt = exact_topk(base, queries, 10)

    spec = args.spec or f"IVF{args.nlist},PQ{args.pq_m}x8,ids=roc,codes=polya"
    print(f"building index: {spec}")
    idx = index_factory(spec).build(base, seed=1)
    is_graph = hasattr(idx, "graph")
    is_ivf = hasattr(idx, "ivf")

    if is_graph:
        search_opts = {"ef": args.ef, "engine": args.engine}
    elif is_ivf:
        search_opts = {"nprobe": args.nprobe, "engine": args.engine}
    else:  # Flat takes no per-search knobs
        search_opts = {}
    policy = BatchPolicy(max_batch=args.max_batch, max_wait_s=0.002)
    if args.shards:
        from repro.shard import (RandomFaults, ShardedAnnService,
                                 plan_shards)
        plan = plan_shards(idx, args.shards)
        sizes = ", ".join(str(s.n_local) for s in plan.shards)
        print(f"sharding: {args.shards} shards by {plan.by} "
              f"({sizes} vectors)")
        faults = (RandomFaults(args.fault_rate, seed=0)
                  if args.fault_rate else None)
        svc = ShardedAnnService(plan, topk=10, cache_mb=args.cache_mb,
                                policy=policy, fault_policy=faults,
                                **search_opts)
    else:
        svc = AnnService(idx, topk=10, cache_mb=args.cache_mb,
                         policy=policy, **search_opts)
    # warm the jit caches off the clock (and keep it out of the stats)
    svc.search(queries[: args.max_batch])
    svc.reset_stats()

    if is_graph:
        per_id = f"{idx.graph.bits_per_edge():.2f}b/edge"
    elif is_ivf:
        per_id = (f"{idx.ivf.bits_per_id():.2f}b ids, "
                  f"{idx.ivf.code_bits_per_element():.2f}b/code-elem")
    else:
        per_id = "raw f32 vectors"

    print(f"serving {args.queries} queries as {args.request_size}-query "
          f"requests (max_batch={args.max_batch})...")
    t0 = time.perf_counter()
    tickets = [svc.submit(queries[i:i + args.request_size])
               for i in range(0, len(queries), args.request_size)]
    svc.flush()  # drain the tail
    wall = time.perf_counter() - t0
    ids = np.concatenate([t.ids for t in tickets], axis=0)
    recall = np.mean([len(set(ids[i]) & set(gt[i])) / 10
                      for i in range(len(queries))])

    st = svc.stats()
    led = svc.memory_ledger()
    print(f"\nrecall@10 (vs exact): {recall:.3f}")
    print(f"throughput:           {len(queries)/wall:,.0f} QPS "
          f"({wall/len(queries)*1e3:.2f} ms/query)")
    print(f"micro-batching:       {st['batches']:.0f} batches, "
          f"mean {st['mean_batch']:.1f} q/batch, "
          f"p99 wait {st['p99_wait_s']*1e3:.2f} ms")
    print(f"id resolve overhead:  {st['resolve_s']/len(queries)*1e6:.0f} us/query "
          f"(late resolution, O(topk)); {st['decodes']:.0f} list decodes "
          f"for {st['queries']:.0f} queries")
    if args.shards:
        print(f"sharded serving:      {st['shards']:.0f} shards, "
              f"merge {st['merge_s']/max(st['search_s'],1e-12):.1%} of "
              f"search wall, p95 latency {st['p95_latency_s']*1e3:.2f} ms")
        print(f"degraded mode:        {st['partial_batches']:.0f}/"
              f"{st['batches']:.0f} partial batches, "
              f"{st['shards_failed']:.0f} shard failures, "
              f"{st['retries']:.0f} retries")
    print(f"\nRAM ledger (ids + codes):")
    print(f"  uncompressed (64b ids):  "
          f"{(led['ids_bytes_unc64'] + led['payload_bytes_unc'])/1e6:8.1f} MB")
    print(f"  compact ids:             "
          f"{(led['ids_bytes_compact'] + led['payload_bytes_unc'])/1e6:8.1f} MB")
    print(f"  this server:             {led['total_bytes']/1e6:8.1f} MB "
          f"({per_id}, decode cache {led['decoded_cache_bytes']/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
