"""End-to-end driver: serve an ANN index with compressed ids (batched).

The paper's deployment scenario: a RAM-resident IVF index answers batched
nearest-neighbor queries; vector ids are ROC-compressed, PQ codes
Polya-compressed, and id resolution is deferred to the final top-k (§4.1).
Reports recall@10 vs exact search, QPS, and the RAM ledger vs the
uncompressed layout.

    PYTHONPATH=src python examples/serve_ann.py [--n 200000] [--queries 2000]
"""

import argparse
import time

import numpy as np

from repro.ann.ivf import IVFIndex
from repro.ann.pq import ProductQuantizer
from repro.data.synthetic import make_dataset


def exact_topk(base, queries, k):
    out = np.zeros((len(queries), k), np.int64)
    for i in range(0, len(queries), 256):
        q = queries[i:i + 256]
        d = (np.sum(q**2, 1, keepdims=True) - 2 * q @ base.T
             + np.sum(base**2, 1)[None])
        out[i:i + 256] = np.argsort(d, 1)[:, :k]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=1_000)
    ap.add_argument("--nlist", type=int, default=1024)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--pq-m", type=int, default=8)
    args = ap.parse_args()

    print(f"dataset: {args.n} x 128 (sift-like)")
    base, queries = make_dataset("sift-like", args.n, args.queries, seed=0)
    gt = exact_topk(base, queries, 10)

    print("building compressed index (ROC ids + Polya PQ codes)...")
    pq = ProductQuantizer(m=args.pq_m, bits=8)
    idx = IVFIndex(nlist=args.nlist, id_codec="roc", pq=pq,
                   code_codec="polya").build(base, seed=1)

    t0 = time.perf_counter()
    ids, _, st = idx.search(queries, nprobe=args.nprobe, topk=10)
    wall = time.perf_counter() - t0
    recall = np.mean([len(set(ids[i]) & set(gt[i])) / 10
                      for i in range(len(queries))])

    compact_bits = np.ceil(np.log2(args.n))
    n = args.n
    ram_unc = n * (64 / 8 + args.pq_m)
    ram_cmp = (n * idx.bits_per_id() / 8
               + n * args.pq_m * idx.code_bits_per_element() / 8)
    print(f"\nrecall@10 (vs exact): {recall:.3f}")
    print(f"throughput:           {len(queries)/wall:,.0f} QPS "
          f"({wall/len(queries)*1e3:.2f} ms/query)")
    print(f"id resolve overhead:  {st.id_resolve_s/len(queries)*1e6:.0f} us/query "
          f"(late resolution, O(topk))")
    print(f"\nRAM ledger (ids + codes):")
    print(f"  uncompressed (64b ids):  {ram_unc/1e6:8.1f} MB")
    print(f"  compact ({compact_bits:.0f}b ids):      "
          f"{n*(compact_bits/8 + args.pq_m)/1e6:8.1f} MB")
    print(f"  this server:             {ram_cmp/1e6:8.1f} MB "
          f"({idx.bits_per_id():.2f}b ids, "
          f"{idx.code_bits_per_element():.2f}b/code-elem)")


if __name__ == "__main__":
    main()
