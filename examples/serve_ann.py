"""End-to-end driver: serve an ANN index with compressed ids (batched).

The paper's deployment scenario: a RAM-resident IVF index answers
nearest-neighbor requests; vector ids are ROC-compressed, PQ codes
Polya-compressed, and id resolution is deferred to the final top-k (§4.1).
Requests stream through :class:`repro.serve.AnnService`, which micro-batches
them (max-batch/max-wait policy) into the blocked scan engine
(repro.ann.scan).  Reports recall@10 vs exact search, QPS, batching and
decode stats, and the RAM ledger vs the uncompressed layout.

    PYTHONPATH=src python examples/serve_ann.py [--n 200000] [--queries 2000]
"""

import argparse
import time

import numpy as np

from repro.ann.ivf import IVFIndex
from repro.ann.pq import ProductQuantizer
from repro.data.synthetic import make_dataset
from repro.serve import AnnService, BatchPolicy


def exact_topk(base, queries, k):
    out = np.zeros((len(queries), k), np.int64)
    for i in range(0, len(queries), 256):
        q = queries[i:i + 256]
        d = (np.sum(q**2, 1, keepdims=True) - 2 * q @ base.T
             + np.sum(base**2, 1)[None])
        out[i:i + 256] = np.argsort(d, 1)[:, :k]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=1_000)
    ap.add_argument("--nlist", type=int, default=1024)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--pq-m", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--request-size", type=int, default=4,
                    help="queries per client request")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "pallas", "xla"])
    args = ap.parse_args()

    print(f"dataset: {args.n} x 128 (sift-like)")
    base, queries = make_dataset("sift-like", args.n, args.queries, seed=0)
    gt = exact_topk(base, queries, 10)

    print("building compressed index (ROC ids + Polya PQ codes)...")
    pq = ProductQuantizer(m=args.pq_m, bits=8)
    idx = IVFIndex(nlist=args.nlist, id_codec="roc", pq=pq,
                   code_codec="polya").build(base, seed=1)

    svc = AnnService(idx, nprobe=args.nprobe, topk=10, engine=args.engine,
                     policy=BatchPolicy(max_batch=args.max_batch,
                                        max_wait_s=0.002))
    # warm the jit caches off the clock (and keep it out of the stats)
    svc.search(queries[:args.max_batch])
    svc.reset_stats()

    print(f"serving {args.queries} queries as {args.request_size}-query "
          f"requests (max_batch={args.max_batch})...")
    t0 = time.perf_counter()
    tickets = [svc.submit(queries[i:i + args.request_size])
               for i in range(0, len(queries), args.request_size)]
    svc.flush()  # drain the tail
    wall = time.perf_counter() - t0
    ids = np.concatenate([t.ids for t in tickets], axis=0)
    recall = np.mean([len(set(ids[i]) & set(gt[i])) / 10
                      for i in range(len(queries))])

    st = svc.stats()
    led = svc.memory_ledger()
    print(f"\nrecall@10 (vs exact): {recall:.3f}")
    print(f"throughput:           {len(queries)/wall:,.0f} QPS "
          f"({wall/len(queries)*1e3:.2f} ms/query)")
    print(f"micro-batching:       {st['batches']:.0f} batches, "
          f"mean {st['mean_batch']:.1f} q/batch, "
          f"p99 wait {st['p99_wait_s']*1e3:.2f} ms")
    print(f"id resolve overhead:  {st['resolve_s']/len(queries)*1e6:.0f} us/query "
          f"(late resolution, O(topk)); {st['decodes']:.0f} list decodes "
          f"for {st['queries']:.0f} queries")
    print(f"\nRAM ledger (ids + codes):")
    print(f"  uncompressed (64b ids):  "
          f"{(led['ids_bytes_unc64'] + led['payload_bytes_unc'])/1e6:8.1f} MB")
    print(f"  compact ids:             "
          f"{(led['ids_bytes_compact'] + led['payload_bytes_unc'])/1e6:8.1f} MB")
    print(f"  this server:             {led['total_bytes']/1e6:8.1f} MB "
          f"({idx.bits_per_id():.2f}b ids, "
          f"{idx.code_bits_per_element():.2f}b/code-elem, "
          f"decode cache {led['decoded_cache_bytes']/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
