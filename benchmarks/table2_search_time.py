"""Paper Table 2 / Figure 2: search wall-time with compressed indices.

IVF{256..2048} x id codecs, flat vectors (max id-decode impact) and
PQ{4,16,32} on IVF1024 (decode impact shrinks as distance compute grows —
the paper's Fig. 2 trend).  Median of `reps` runs over a query batch, plus
the id-resolution time isolated (the paper's §4.1 trick makes it O(topk)).
N=200k, 1k queries (paper: 1M / 10k — CPU-budget scale, noted).

Times are produced by the **batched scan engine** (repro.ann.scan): the
per-query Python loop would swamp the id-decode signal with interpreter
overhead; the blocked path isolates it.  The decoded-list LRU is cleared
between reps so every rep pays cold decodes (decodes == distinct probed
clusters — the invariant the engine guarantees per batch).
"""

from __future__ import annotations

import numpy as np

from repro.api import index_factory
from repro.data.synthetic import make_dataset

from .common import DATASETS, emit, save_result

N = 200_000
NQ = 500
CODECS = ("unc64", "compact", "ef", "wt", "wt1", "roc", "gap_ans")


_CENTROIDS = {}


def _coarse(base, nlist, preset):
    key = (preset, nlist)
    if key not in _CENTROIDS:
        from repro.ann.kmeans import kmeans

        _CENTROIDS[key] = kmeans(base, nlist, iters=8, seed=1)
    return _CENTROIDS[key]


def run_config(base, queries, nlist, codec, pq_m=0, pq_bits=8, reps=2,
               preset="", engine="auto"):
    spec = f"IVF{nlist}" + (f",PQ{pq_m}x{pq_bits}" if pq_m else "") \
        + f",ids={codec}"
    idx = index_factory(spec).build(
        base, seed=1, centroids=_coarse(base, nlist, preset))
    # warm the jit caches off the clock, then time cold-decode reps
    idx.search(queries[:64], k=10, nprobe=16, engine=engine)
    walls, res, decodes, distinct = [], [], [], []
    for _ in range(reps):
        idx.ivf.decoded_cache.clear()
        _, _, st = idx.search(queries, k=10, nprobe=16, engine=engine)
        walls.append(st.wall_s)
        res.append(st.id_resolve_s)
        decodes.append(st.decodes)
        distinct.append(st.distinct_probed)
    return {
        "spec": idx.spec,
        "wall_s": float(np.median(walls)),
        "id_resolve_s": float(np.median(res)),
        "bits_per_id": idx.ivf.bits_per_id(),
        "decodes": int(np.median(decodes)),
        "distinct_probed": int(np.median(distinct)),
        "engine": engine,
    }


def main(quick: bool = False):
    rows = {}
    datasets = DATASETS if not quick else DATASETS[:1]
    nlists = (256, 512, 1024, 2048) if not quick else (1024,)
    codecs = CODECS if not quick else ("unc64", "roc", "wt")
    nq = NQ if not quick else 200
    for preset in datasets:
        base, queries = make_dataset(preset, N, nq, seed=0)
        for nlist in nlists:
            for codec in codecs:
                key = f"{preset}/IVF{nlist}/{codec}"
                rows[key] = run_config(base, queries, nlist, codec, preset=preset)
                emit(f"table2/{key}", rows[key]["wall_s"] * 1e6 / nq,
                     f"bpe={rows[key]['bits_per_id']:.2f}")
        # Fig 2: PQ dimension sweep on IVF1024 (primary preset only)
        if not quick and preset == "sift-like":
            for m in (4, 16, 32):
                for codec in ("unc64", "roc", "wt", "gap_ans"):
                    key = f"{preset}/IVF1024-PQ{m}/{codec}"
                    rows[key] = run_config(base, queries, 1024, codec, pq_m=m, preset=preset)
                    emit(f"table2/{key}", rows[key]["wall_s"] * 1e6 / nq,
                         f"bpe={rows[key]['bits_per_id']:.2f}")
    save_result("table2_search_time", {"n": N, "nq": nq, "rows": rows})
    return rows


if __name__ == "__main__":
    main()
