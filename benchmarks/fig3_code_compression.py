"""Paper Figure 3: lossless compression of PQ codes conditioned on clusters.

The Eq. (6)-(7) Polya coder on IVF1024 PQ codes for the three synthetic
datasets: sift-like (strong block structure -> compressible, the paper's
~19% case), deep-like (mild), ssnpp-like (incompressible, ~0%).  The
unconditional entropy of the codes is reported alongside to confirm the
~8.0 bits baseline (no compression possible without conditioning).
"""

from __future__ import annotations

import numpy as np

from repro.ann.pq import ProductQuantizer
from repro.core.polya import polya_encode_clusters
from repro.data.synthetic import make_dataset

from .common import DATASETS, Timer, emit, ivf_partition, save_result

N = 200_000
K = 1024
MS = (4, 8, 16, 32)


def column_entropy(codes: np.ndarray) -> float:
    h = 0.0
    for j in range(codes.shape[1]):
        c = np.bincount(codes[:, j], minlength=256)
        p = c[c > 0] / c.sum()
        h += float(-(p * np.log2(p)).sum())
    return h / codes.shape[1]


def run(preset: str, m: int) -> dict:
    base, _ = make_dataset(preset, N, 10, seed=0)
    a = ivf_partition(preset, N, K)
    pq = ProductQuantizer(m=m, bits=8).train(
        base[np.random.default_rng(0).choice(N, 50_000, replace=False)], iters=4)
    codes = pq.encode(base)
    order = np.argsort(a, kind="stable")
    sizes = np.bincount(a, minlength=K)
    grouped = np.split(codes[order], np.cumsum(sizes)[:-1])
    grouped = [g for g in grouped if g.shape[0] > 0]
    with Timer() as t:
        _, _, bits = polya_encode_clusters(grouped)
    bpe = bits / (codes.shape[0] * m)
    return {
        "bpe": bpe,
        "unconditional_entropy": column_entropy(codes),
        "savings_pct": 100 * (1 - bpe / 8.0),
        "enc_s": t.s,
    }


def main(quick: bool = False):
    rows = {}
    datasets = DATASETS if not quick else DATASETS[:1]
    for preset in datasets:
        ms = (8,) if (quick or preset != "sift-like") else MS
        for m in ms:
            key = f"{preset}/PQ{m}"
            rows[key] = run(preset, m)
            emit(f"fig3/{key}", 0.0,
                 f"{rows[key]['bpe']:.2f}bpe,{rows[key]['savings_pct']:.1f}%")
    save_result("fig3_code_compression", {"n": N, "k": K, "rows": rows})
    return rows


if __name__ == "__main__":
    main()
