"""Paper Table 3: offline whole-graph compression — REC vs zuckerli-lite.

HNSW/NSG graphs at several degree caps; the whole edge list goes through
(a) REC with the static-degree streaming model, (b) REC with the exact
Polya urn (paper's model, measured on a subsampled graph — quadratic
coder), and (c) webgraph-lite (the Zuckerli stand-in).  Reported in
bits-per-edge vs the compact log2(N) reference; the REC > per-node-ROC gap
(log E! vs sum log m_i!) is the paper's §5.3 claim, checked explicitly.

The online (per-node ROC) reference row and the offline index *artifact*
both go through the ``repro.api`` factory path: the graph index is built
from a spec string and its RIDX v2 container (friend lists via the
webgraph-lite section) is sized alongside the raw edge-stream rates.
Search timing for compressed graphs lives in table2/spec_bench — this
table is offline rates only, batched-API era (no per-query
``search_ref`` loops left here).
"""

from __future__ import annotations

import math

import numpy as np

from repro.api import index_factory, save_index
from repro.core import rec_encode
from repro.core.webgraph_lite import webgraph_encode
from repro.data.synthetic import make_dataset

from .common import DATASETS, Timer, emit, graph_adj, save_result

N = 30_000
RS = (16, 32)


def edge_list(adj):
    src = np.concatenate([np.full(len(a), i, np.int64) for i, a in enumerate(adj)])
    dst = np.concatenate(adj)
    return np.stack([src, dst], axis=1)


def run_graph(preset: str, n: int, r: int, kind: str, polya_cap: int = 60_000):
    adj = graph_adj(preset, n, r, kind)
    edges = edge_list(adj)
    E = edges.shape[0]
    out = {"edges": E, "compact": float(math.ceil(math.log2(n)))}

    with Timer() as t:
        res = rec_encode(edges, n, model="degree")
    out["rec_degree"] = res.total_bits / E
    out["rec_degree_payload"] = res.payload_bits / E
    out["rec_enc_s"] = t.s

    # exact Polya-urn REC on a node-subsampled graph (quadratic coder)
    if E > polya_cap:
        keep_n = max(2, int(n * polya_cap / E))
        sub_adj = [a[a < keep_n] for a in adj[:keep_n]]
        sub_edges = edge_list(sub_adj)
    else:
        keep_n, sub_edges = n, edges
    if sub_edges.shape[0] > 10:
        res_p = rec_encode(sub_edges, keep_n, model="polya")
        out["rec_polya_sub"] = res_p.payload_bits / sub_edges.shape[0]
        out["rec_polya_sub_n"] = keep_n
        out["rec_polya_sub_compact"] = float(math.ceil(math.log2(keep_n)))

    with Timer() as t:
        ans = webgraph_encode(adj, n)
    out["zuckerli_lite"] = ans.bits / E
    out["zuck_enc_s"] = t.s

    # per-node ROC (online setting) for the offline-vs-online gap — built
    # through the factory so the number measures exactly what the served
    # index stores
    base, _ = make_dataset(preset, n, 10, seed=0)
    gidx = index_factory(f"{kind.upper()}{r},ids=roc").build(base, adj=adj)
    out["roc_per_node"] = gidx.graph.id_bits() / E
    # the offline artifact as a first-class unit: RIDX v2 container size
    # (vectors ride along as raw f32; the id payload is the delta of note)
    blob = save_index(gidx)
    out["ridx_container_bytes"] = len(blob)
    out["ridx_container_id_bits_per_edge"] = (
        (len(blob) - gidx.graph.x.nbytes) * 8 / E)
    return out


def main(quick: bool = False):
    rows = {}
    n = 10_000 if quick else N
    rs = (16,) if quick else RS
    # two presets bracket the paper's easy/hard regimes (CPU budget)
    datasets = ("sift-like", "ssnpp-like") if not quick else DATASETS[:1]
    for preset in datasets:
        for kind in ("nsg", "hnsw"):
            for r in rs:
                key = f"{preset}/{kind.upper()}{r}"
                rows[key] = run_graph(preset, n, r, kind)
                emit(f"table3/{key}/rec", 0.0,
                     f"{rows[key]['rec_degree']:.2f}bpe")
    save_result("table3_offline_graph", {"n": n, "rows": rows})
    return rows


if __name__ == "__main__":
    main()
