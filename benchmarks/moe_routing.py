"""Beyond-paper: ROC compression of MoE routing tables.

Top-k routing produces, per expert, an order-invariant *set* of token ids —
exactly the IVF inverted-list structure the paper compresses.  Offloaded /
logged routing traces (olmoe-style: 64 experts, top-8) are compressed with
ROC and gap-ANS vs the compact baseline; savings follow the same
log(N_e!) law.  Router probabilities come from an actual reduced-olmoe
forward pass so the expert load imbalance is realistic.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import BigANS, roc_push_set
from repro.core.gap_ans import GapAnsCodec
from repro.models import build

from .common import emit, save_result


def routing_trace(n_tokens: int = 16_384, seed: int = 0):
    """Expert assignment sets from a reduced-olmoe router."""
    cfg = reduced(get_config("olmoe-1b-7b"))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    router_k = params["segments"][0]["moe"]["router"]["kernel"][0]
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n_tokens, router_k.shape[0])).astype(np.float32)
    logits = x @ np.asarray(router_k)
    top = np.argsort(-logits, axis=1)[:, : cfg.experts_per_token]
    E = cfg.n_experts
    lists = [np.flatnonzero((top == e).any(axis=1)).astype(np.int64)
             for e in range(E)]
    return lists, n_tokens, E, cfg.experts_per_token


def main(quick: bool = False):
    lists, T, E, k = routing_trace(4096 if quick else 16_384)
    assignments = sum(len(l) for l in lists)
    compact = math.ceil(math.log2(T))
    roc_bits = 0
    for l in lists:
        s = BigANS()
        roc_push_set(s, l, T)
        roc_bits += s.bits
    gc = GapAnsCodec()
    gap_bits = sum(gc.size_bits(gc.encode(l, T)) for l in lists)
    out = {
        "tokens": T, "experts": E, "topk": k,
        "assignments": assignments,
        "compact_bits_per_assign": compact,
        "roc_bits_per_assign": roc_bits / assignments,
        "gap_bits_per_assign": gap_bits / assignments,
        "compression_ratio": compact * assignments / roc_bits,
    }
    emit("moe_routing/roc", 0.0,
         f"{out['roc_bits_per_assign']:.2f}b vs {compact}b compact")
    save_result("moe_routing", out)
    return out


if __name__ == "__main__":
    main()
