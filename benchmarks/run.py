"""Benchmark orchestrator — one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines (the scaffold contract) and
writes structured JSON to experiments/results/.  ``--quick`` shrinks data
sizes for smoke use; default sizes reproduce the paper-comparable numbers.
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: table1 table2 table3 table4 fig3 moe codec "
                         "roofline graph spec shard ingest select")
    ap.add_argument("--spec", action="append", default=None,
                    help="factory spec string for the 'spec' suite "
                         "(repeatable); implies --only spec when --only is "
                         "not given")
    args = ap.parse_args()

    from . import (codec_speed, fig3_code_compression, graph_bench,
                   ingest_bench, moe_routing, roofline, select_bench,
                   shard_bench, spec_bench, table1_bpe, table2_search_time,
                   table3_offline_graph, table4_large_scale)

    suites = {
        "table1": table1_bpe.main,
        "table2": table2_search_time.main,
        "table3": table3_offline_graph.main,
        "table4": table4_large_scale.main,
        "fig3": fig3_code_compression.main,
        "moe": moe_routing.main,
        "codec": codec_speed.main,
        "roofline": roofline.main,
        "graph": graph_bench.main,
        "shard": shard_bench.main,
        "ingest": ingest_bench.main,
        "select": select_bench.main,
        "spec": lambda quick=False: spec_bench.main(quick=quick,
                                                    specs=args.spec),
    }
    chosen = args.only or (["spec"] if args.spec else
                           [n for n in suites if n != "spec"])
    for name in chosen:
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            suites[name](quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/FAILED,0.0,{type(e).__name__}:{e}", flush=True)
            continue
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
