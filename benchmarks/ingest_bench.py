"""Online ingest: epoch append vs full re-encode.

Before the epoch scheme, ``IVFIndex.add`` had to re-encode every id
stream against the grown universe — O(n) entropy coding per append.
Epochs make the append O(Δ): only the new rows' ids (and PQ codes) are
coded, at the price of a bits-per-id overhead until compaction folds the
epochs back together.

This suite measures both sides of that trade at the ISSUE's reference
point (n = 100k, Δ = 1k): per-codec wall time of one epoch append vs the
O(n) fold (``compact()``, the work a rebuild-style add must do), and the
bpv overhead of holding several epochs vs the compacted single-universe
rate.  Emits ``ingest/...`` CSV lines and writes
experiments/results/ingest_bench.json.
"""

from __future__ import annotations

import time

import numpy as np

from .common import emit, save_result


def _bench_codec(spec: str, base: np.ndarray, deltas, quick: bool) -> dict:
    from repro.api import index_factory

    idx = index_factory(spec).build(base, seed=0)
    # warm-up epoch: jit-compiles assign/PQ-encode off the clock, then
    # compact folds it away so the timed append starts from one epoch
    idx.add(deltas[0])
    idx.ivf.compact()
    bpv_compact0 = idx.ivf.bits_per_id()

    # one epoch append, timed (entropy-codes Δ ids + O(n) memcpy regroup)
    t0 = time.perf_counter()
    idx.add(deltas[1])
    t_append = time.perf_counter() - t0

    # remaining appends: how bpv drifts as epochs pile up
    for d in deltas[2:]:
        idx.add(d)
    bpv_epoched = idx.ivf.bits_per_id()
    n_epochs = idx.ivf.n_epochs

    # the rebuild baseline: re-encode every list at the grown universe —
    # exactly what a non-epoched add() had to do per append
    t0 = time.perf_counter()
    idx.ivf.compact()
    t_rebuild = time.perf_counter() - t0
    bpv_compact = idx.ivf.bits_per_id()

    speedup = t_rebuild / max(t_append, 1e-9)
    row = {
        "spec": spec,
        "n": int(base.shape[0]),
        "delta": int(deltas[0].shape[0]),
        "epochs_held": int(n_epochs),
        "append_ms": 1e3 * t_append,
        "rebuild_ms": 1e3 * t_rebuild,
        "speedup": speedup,
        "bpv_compact_before": bpv_compact0,
        "bpv_epoched": bpv_epoched,
        "bpv_compact": bpv_compact,
        "bpv_overhead_pct": 100.0 * (bpv_epoched - bpv_compact)
        / max(bpv_compact, 1e-9),
    }
    emit(f"ingest/append/{spec}", 1e6 * t_append,
         f"speedup_vs_rebuild={speedup:.1f}x")
    emit(f"ingest/bpv/{spec}", 0.0,
         f"epoched={bpv_epoched:.3f};compact={bpv_compact:.3f};"
         f"overhead={row['bpv_overhead_pct']:.1f}%")
    return row


def main(quick: bool = False) -> None:
    from repro.data.synthetic import make_dataset

    n = 20_000 if quick else 100_000
    delta = 200 if quick else 1_000
    n_appends = 5                      # first one is the untimed warm-up
    nlist = 64 if quick else 256

    base, _ = make_dataset("sift-like", n + n_appends * delta, 8, seed=0)
    x0, rest = base[:n], base[n:]
    deltas = [rest[i * delta:(i + 1) * delta] for i in range(n_appends)]

    specs = [f"IVF{nlist},ids=roc", f"IVF{nlist},ids=gap_ans",
             f"IVF{nlist},ids=ef", f"IVF{nlist},ids=wt1",
             f"IVF{nlist},PQ8x8,ids=roc,codes=polya"]
    rows = [_bench_codec(s, x0, deltas, quick) for s in specs]

    path = save_result("ingest_bench", {
        "n": n, "delta": delta, "n_appends": n_appends, "rows": rows})
    # the headline number is the stream codecs (roc/gap_ans/polya): their
    # O(n) ANS re-encode is exactly what the epoch scheme removes.
    # Random-access codecs (ef/wt) were never entropy-coding bound, so
    # their append is dominated by the shared O(n) regroup memcpy.
    stream = [r for r in rows
              if "roc" in r["spec"] or "gap_ans" in r["spec"]]
    worst = min(r["speedup"] for r in stream)
    emit("ingest/summary", 0.0,
         f"stream_min_speedup={worst:.1f}x;json={path.name}")


if __name__ == "__main__":
    main()
