"""Batched graph-search engine vs the per-query reference loop.

Times ``NSG32,ids=roc`` (the paper's Table 2 graph operating point) at
batch sizes >= 32: the beam-batched engine (repro.ann.graph_scan) against
``search_ref``, interleaved min-of-k so the two paths see the same
machine noise.  Also checks the decode-sharing claim: the batched
engine's decode count must not exceed the number of *distinct* friend
lists expanded per step (``visited - dedup_hits``).

Emits ``graph/<case>`` CSV lines and experiments/results/graph_bench.json.
"""

from __future__ import annotations

import numpy as np

from .common import Timer, emit, save_result

SPEC = "NSG32,ids=roc"


def _qps(fn, nq: int, repeats: int) -> float:
    best = np.inf
    for _ in range(repeats):
        with Timer() as t:
            fn()
        best = min(best, t.s)
    return nq / best


def main(quick: bool = False) -> None:
    from repro.api import index_factory
    from repro.data.synthetic import make_dataset

    n = 4000 if quick else 20000
    repeats = 3 if quick else 15
    ef = 32
    base, queries = make_dataset("deep-like", n, 128, seed=0)
    idx = index_factory(SPEC).build(base, seed=1)
    g = idx.graph

    rows = []
    for batch in (32, 64, 128):
        q = queries[:batch]
        # warm both paths off-clock (jit compiles, decode cache parity)
        g.search(q, ef=ef, topk=10)
        g.search_ref(q, ef=ef, topk=10)
        # interleave so drift hits ref and batched alike
        best_ref = best_bat = np.inf
        for _ in range(repeats):
            with Timer() as t:
                g.search_ref(q, ef=ef, topk=10)
            best_ref = min(best_ref, t.s)
            with Timer() as t:
                g.search(q, ef=ef, topk=10)
            best_bat = min(best_bat, t.s)
        qps_ref = batch / best_ref
        qps_bat = batch / best_bat

        g.decoded_cache.clear()          # make the decode delta observable
        ids_b, d_b, st = g.search(q, ef=ef, topk=10)
        ids_r, d_r, _ = g.search_ref(q, ef=ef, topk=10)
        exact = bool(np.array_equal(ids_b, ids_r) and np.array_equal(d_b, d_r))
        distinct_lists = st.visited - st.dedup_hits
        dedup_ok = bool(0 < st.decodes <= distinct_lists)

        case = f"{SPEC}/batch{batch}/ef{ef}"
        emit(f"graph/{case}", 1e6 / qps_bat,
             f"qps={qps_bat:.0f} ref_qps={qps_ref:.0f} "
             f"speedup={qps_bat / qps_ref:.2f}x exact={exact} "
             f"decodes={st.decodes}<=lists={distinct_lists}:{dedup_ok}")
        rows.append({
            "spec": SPEC, "batch": batch, "ef": ef, "n": n,
            "qps_batched": qps_bat, "qps_ref": qps_ref,
            "speedup": qps_bat / qps_ref, "exact": exact,
            "steps": st.steps, "frontier_size": st.frontier_size,
            "decodes": st.decodes, "dedup_hits": st.dedup_hits,
            "visited": st.visited, "distinct_lists": distinct_lists,
            "dedup_ok": dedup_ok,
        })

    save_result("graph_bench", {"spec": SPEC, "quick": quick, "rows": rows})


if __name__ == "__main__":
    main(quick=True)
