"""Scatter/merge overhead: sharded vs monolithic serving QPS.

The router's merge is bit-identical to the unsharded index, so the only
question is cost: what does fanning a query batch out to N shard workers
and k-way merging the answers cost versus one monolithic search?  On one
machine (threads, shared memory bandwidth) sharding buys no capacity —
the point of the number is the *overhead floor* of the scatter/merge
path that a multi-machine deployment would amortize.

Sweeps shards x batch size on one IVF spec; emits QPS for the monolithic
service and each shard count, plus merge-time share.  JSON lands in
experiments/results/shard_bench.json.
"""

from __future__ import annotations

import numpy as np

from .common import Timer, emit, save_result


def _qps(svc, queries, batch: int, repeats: int) -> float:
    svc.search(queries[:batch])          # warm jit caches off the clock
    svc.reset_stats()
    with Timer() as t:
        for _ in range(repeats):
            for i in range(0, len(queries), batch):
                svc.search(queries[i:i + batch])
    return repeats * len(queries) / t.s


def main(quick: bool = False) -> None:
    from repro.api import index_factory
    from repro.data.synthetic import make_dataset
    from repro.serve import AnnService
    from repro.shard import ShardedAnnService, plan_shards

    n = 20_000 if quick else 200_000
    nq = 256 if quick else 1024
    repeats = 1 if quick else 3
    spec = "IVF64,ids=roc" if quick else "IVF512,ids=roc"
    nprobe = 8 if quick else 16

    base, queries = make_dataset("sift-like", n, nq, seed=0)
    mono = index_factory(spec).build(base, seed=1)

    rows = []
    for batch in (32, 128):
        svc = AnnService(mono, topk=10, nprobe=nprobe)
        mono_qps = _qps(svc, queries, batch, repeats)
        emit(f"shard/mono_b{batch}", 1e6 / mono_qps, f"{mono_qps:.0f}qps")
        rows.append({"shards": 0, "batch": batch, "qps": mono_qps,
                     "merge_share": 0.0})
        for nshards in (1, 2, 4):
            plan = plan_shards(mono, nshards)
            svc = ShardedAnnService(plan, topk=10, nprobe=nprobe)
            qps = _qps(svc, queries, batch, repeats)
            st = svc.stats()
            svc.close()
            merge_share = st["merge_s"] / max(st["search_s"], 1e-12)
            emit(f"shard/s{nshards}_b{batch}", 1e6 / qps,
                 f"{qps:.0f}qps;{qps / mono_qps:.2f}x;"
                 f"merge={merge_share:.1%}")
            rows.append({"shards": nshards, "batch": batch, "qps": qps,
                         "vs_mono": qps / mono_qps,
                         "merge_share": merge_share})
    save_result("shard_bench", {"spec": spec, "n": n, "nprobe": nprobe,
                                "rows": rows})


if __name__ == "__main__":
    main(quick=True)
