"""Generic factory-spec benchmark — sweep any point of the codec×structure
matrix from the command line.

One ``--spec`` string (repeatable) names the index; for each spec this
builds it through ``repro.api.index_factory``, times a batched search,
round-trips the RIDX v2 container, and reports bits/id (or bits/edge),
QPS, decode counts and the memory ledger.  This is the "one flag sweeps
the paper's tables" entry point:

    PYTHONPATH=src python -m benchmarks.run --only spec \\
        --spec "IVF1024,PQ8x8,ids=roc,codes=polya" --spec "NSG16,ids=ef"
"""

from __future__ import annotations

import numpy as np

from repro.api import index_factory, load_index, save_index
from repro.data.synthetic import make_dataset

from .common import Timer, emit, save_result

DEFAULT_SPECS = (
    "Flat",
    "IVF256,ids=roc",
    "IVF256,ids=wt",
    "IVF256,PQ8x8,ids=roc,codes=polya",
    "NSG16,ids=roc",
)

N_IVF = 100_000
N_GRAPH = 5_000
NQ = 200


def run_spec(spec: str, quick: bool = False) -> dict:
    idx = index_factory(spec)
    is_graph = hasattr(idx, "graph")
    n = (N_GRAPH if is_graph else N_IVF) // (10 if quick else 1)
    nq = NQ // (4 if quick else 1)
    base, queries = make_dataset("sift-like", n, nq, seed=0)

    with Timer() as t_build:
        idx.build(base, seed=1)
    # warm jit caches off the clock
    idx.search(queries[:32], k=10)
    with Timer() as t_search:
        dists, ids, st = idx.search(queries, k=10)

    with Timer() as t_save:
        blob = save_index(idx)
    idx2 = load_index(blob)
    d2, i2, _ = idx2.search(queries, k=10)
    lossless = bool(np.array_equal(ids, i2) and np.array_equal(dists, d2))

    led = idx.memory_ledger()
    out = {
        "spec": idx.spec,
        "n": n,
        "build_s": t_build.s,
        "search_s": t_search.s,
        "us_per_query": t_search.s / nq * 1e6,
        "ndis": st.ndis,
        "decodes": st.decodes,
        "engine": st.engine,
        "container_bytes": len(blob),
        "pack_s": t_save.s,
        "reload_bit_identical": lossless,
        "ledger": led,
    }
    if is_graph:
        out["bits_per_edge"] = idx.graph.bits_per_edge()
    elif hasattr(idx, "ivf"):
        out["bits_per_id"] = idx.ivf.bits_per_id()
    return out


def main(quick: bool = False, specs=None):
    rows = {}
    for spec in specs or DEFAULT_SPECS:
        rows[spec] = run_spec(spec, quick=quick)
        r = rows[spec]
        rate = r.get("bits_per_id", r.get("bits_per_edge", 0.0))
        emit(f"spec/{spec}", r["us_per_query"],
             f"{rate:.2f}b,{r['container_bytes']}B,"
             f"lossless={r['reload_bit_identical']}")
        assert r["reload_bit_identical"], f"{spec}: reload changed results"
    save_result("spec_bench", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
