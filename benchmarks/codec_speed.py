"""Codec micro-benchmarks: encode/decode throughput per codec.

Feeds EXPERIMENTS.md §Perf (codec lane): paper-faithful sequential ROC vs
the TPU-adapted vectorized gap-ANS (numpy model of the Pallas kernel) vs
EF/WT access.  ids/s and MB/s of decoded ids.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BigANS, EliasFano, WaveletTree, roc_pop_set, roc_push_set
from repro.core.gap_ans import GapAnsCodec
from repro.core.vrans import VRans16Decoder, VRans16Encoder

from .common import emit, save_result


def bench(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(quick: bool = False):
    n_total = 100_000 if quick else 1_000_000
    k = n_total // 977
    rng = np.random.default_rng(0)
    a = rng.integers(0, k, size=n_total)
    order = np.argsort(a, kind="stable")
    sizes = np.bincount(a, minlength=k)
    lists = np.split(order, np.cumsum(sizes)[:-1])
    out = {}

    # ROC (paper-faithful, exact sequential)
    streams = []
    enc_s = bench(lambda: [streams.clear()] and None or streams.extend(
        _roc_enc(lists, n_total)), reps=1)
    dec_s = bench(lambda: [roc_pop_set(BigANS(s.state), len(l), n_total)
                           for s, l in zip(streams, lists)], reps=1)
    out["roc"] = {"enc_ids_per_s": n_total / enc_s, "dec_ids_per_s": n_total / dec_s}
    emit("codec_speed/roc_dec", dec_s / n_total * 1e6, f"{n_total/dec_s:.0f} ids/s")

    # gap-ANS vectorized (TPU path model)
    gc = GapAnsCodec()
    blobs = []
    enc_s = bench(lambda: (blobs.clear(), blobs.extend(
        gc.encode(l, n_total) for l in lists))[-1] and None, reps=1)
    dec_s = bench(lambda: [gc.decode(b, n_total) for b in blobs], reps=1)
    out["gap_ans"] = {"enc_ids_per_s": n_total / enc_s, "dec_ids_per_s": n_total / dec_s}
    emit("codec_speed/gap_dec", dec_s / n_total * 1e6, f"{n_total/dec_s:.0f} ids/s")

    # EF decode + random access
    efs = [EliasFano.encode(l, n_total) for l in lists]
    dec_s = bench(lambda: [e.decode() for e in efs], reps=1)
    out["ef"] = {"dec_ids_per_s": n_total / dec_s}
    nacc = 10_000
    acc_s = bench(lambda: [efs[i % k].access(0) for i in range(nacc)], reps=1)
    out["ef"]["access_us"] = acc_s / nacc * 1e6
    emit("codec_speed/ef_access", acc_s / nacc * 1e6, "")

    # WT select
    wt = WaveletTree.build(a, k, compressed=False)
    nsel = 2_000
    ks = rng.integers(0, k, nsel)
    sel_s = bench(lambda: [wt.select(int(kk), 0) for kk in ks], reps=1)
    out["wt"] = {"select_us": sel_s / nsel * 1e6}
    emit("codec_speed/wt_select", sel_s / nsel * 1e6, "")
    wt1 = WaveletTree.build(a, k, compressed=True)
    sel_s = bench(lambda: [wt1.select(int(kk), 0) for kk in ks[:500]], reps=1)
    out["wt1"] = {"select_us": sel_s / 500 * 1e6}
    emit("codec_speed/wt1_select", sel_s / 500 * 1e6, "")

    # raw interleaved vrANS16 lane decode (kernel's numpy model)
    L, rows, r = 128, 2000, 12
    data = rng.integers(0, 1 << r, size=(rows, L))
    enc = VRans16Encoder(L)
    for t in range(rows - 1, -1, -1):
        enc.push_uniform(data[t], r)
    heads, words = enc.finalize()
    def dec_all():
        d = VRans16Decoder(heads, words)
        for _ in range(rows):
            d.pop_uniform(r)
    dec_s = bench(dec_all)
    nsym = rows * L
    out["vrans16"] = {"dec_syms_per_s": nsym / dec_s}
    emit("codec_speed/vrans16_dec", dec_s / nsym * 1e6, f"{nsym/dec_s:.0f} sym/s")

    save_result("codec_speed", out)
    return out


def _roc_enc(lists, n_total):
    streams = []
    for l in lists:
        s = BigANS()
        roc_push_set(s, l, n_total)
        streams.append(s)
    return streams


if __name__ == "__main__":
    main()
