"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run.

    compute_s    = HLO_FLOPs_per_device / 197e12      (bf16 peak, TPU v5e)
    memory_s     = HLO_bytes_per_device / 819e9       (HBM bw)
    collective_s = collective_bytes_per_device / 50e9 (per-link ICI)

``cost_analysis()`` semantics (per-device vs global) are *calibrated* in a
subprocess against a matmul of known FLOPs before being trusted.  The
dominant term, MODEL_FLOPS=6ND (or 6·N_active·D) ratio, and a what-to-fix
hint are derived per cell; output feeds EXPERIMENTS.md §Roofline directly.
"""

from __future__ import annotations

import json
import numpy as np
import subprocess
import sys
from pathlib import Path

from .common import ROOT, emit, save_result

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN = ROOT / "experiments" / "dryrun"

_CALIB_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("d",))
xs = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
ws = jax.ShapeDtypeStruct((512, 256), jnp.float32)
f = jax.jit(lambda x, w: x @ w,
            in_shardings=(NamedSharding(mesh, P("d", None)), NamedSharding(mesh, P())))
c = f.lower(xs, ws).compile()
ca = c.cost_analysis()
if isinstance(ca, list):  # old jax returns one dict per computation
    ca = ca[0]
flops = ca["flops"]
global_flops = 2 * 1024 * 512 * 256
print(flops / global_flops)
"""


def calibrate() -> float:
    """Returns cost_analysis flops / global flops (≈1/n_dev ⇒ per-device)."""
    out = subprocess.run([sys.executable, "-c", _CALIB_SRC],
                         capture_output=True, text=True, timeout=300)
    ratio = float(out.stdout.strip().splitlines()[-1])
    return ratio


def analytic_memory_bytes(arch: str, shape_name: str, kind: str,
                          n_dev: int) -> float:
    """Analytic per-device HBM traffic model (fused-TPU assumption).

    XLA-CPU's ``bytes accessed`` counts every unfused op's operands — 10-100x
    above fused HBM reality — so the memory term comes from the exact tensor
    inventory instead (params/optimizer/grad passes + activation stream +
    KV-cache reads), all computed from the real configs and shardings:

      train:   32 B/param/dev (f32 master r+w, bf16 cast r x2, grad f32 r+w,
               m+v r+w) + activations ~12 B/token/layer/d_model x3 passes
               (fwd + remat-fwd + bwd) + logits f32.
      prefill: 2 B/param/dev + activation stream x1 + KV write.
      decode:  2 B/param/dev + full KV-cache read per token + state r/w.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config
    from repro.models import build, count_params
    from repro.models.encdec import dec_len_for

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_params = count_params(cfg)
    p_dev = n_params / n_dev
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers + (cfg.n_encoder_layers if cfg.encoder_decoder else 0)
    tokens_dev = B * S / n_dev
    if cfg.encoder_decoder and kind != "decode":
        tokens_dev = B * (S + dec_len_for(S)) / n_dev

    tp = 16  # model-axis width of the production mesh
    logits_traffic = 2 * 4.0 * tokens_dev * cfg.padded_vocab / tp  # f32 w+r
    if kind == "train":
        param_traffic = 32.0 * p_dev
        act = 12.0 * tokens_dev * d * 2 * L * 3
        return param_traffic + act + logits_traffic
    if kind == "prefill":
        return 2.0 * p_dev + 12.0 * tokens_dev * d * 2 * L + logits_traffic / 2
    # decode: params + cache read per token + writes
    model = build(cfg)
    kw = {"mem_len": S} if cfg.encoder_decoder else {}
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=jnp.bfloat16, **kw))
    cache_bytes = sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(cache_shapes))
    return 2.0 * p_dev + 1.05 * cache_bytes / n_dev


def analyze(rec: dict, per_device_ratio: float, probe: dict | None = None) -> dict:
    n_dev = rec["n_devices"]
    # cost_analysis is per-device if ratio ~ 1/8 in the 8-dev calibration
    per_device = per_device_ratio < 0.5
    flops_dev = rec["cost"]["flops"] if per_device else rec["cost"]["flops"] / n_dev
    raw_bytes_dev = (rec["cost"]["bytes_accessed"] if per_device
                     else rec["cost"]["bytes_accessed"] / n_dev)
    coll_dev = rec["collectives"]["total_bytes"]  # HLO shapes are per-device
    if probe and probe.get("status") == "ok":
        # scans under-count (while bodies counted once): prefer the unrolled
        # probe extrapolation (see dryrun.run_probe) for flops/collectives
        flops_dev = probe["flops"]
        coll_dev = probe["collective_bytes"]
    bytes_dev = analytic_memory_bytes(rec["arch"], rec["shape"], rec["kind"],
                                      n_dev)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    model_flops_dev = rec["model_flops_global"] / n_dev
    useful = model_flops_dev / flops_dev if flops_dev > 0 else 0.0
    mfu_bound = (model_flops_dev / PEAK_FLOPS) / step_s if step_s > 0 else 0.0
    hints = {
        "compute_s": "reduce recompute (remat policy) / keep MXU dims aligned",
        "memory_s": "fuse element-wise chains; widen per-step arithmetic intensity",
        "collective_s": "reshard to cut all-gathers; overlap collectives with compute",
    }
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "roofline_step_s": round(step_s, 6),
        "model_flops_ratio": round(useful, 4),
        "roofline_fraction": round(mfu_bound, 4),
        "hint": hints[dominant],
    }


def main(quick: bool = False):
    ratio = calibrate()
    probes_dir = ROOT / "experiments" / "probes"
    rows = {}
    for p in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            rows[p.stem] = {"status": rec.get("status", "missing"),
                            "error": rec.get("error", "")[:200]}
            continue
        if rec["mesh"] != "16x16":
            # the roofline table is single-pod only (the multi-pod compile is
            # the pod-axis shard proof); multi-pod cells have no cost probes
            continue
        probe = None
        pp = probes_dir / f"{rec['arch']}__{rec['shape']}.json"
        if pp.exists():
            probe = json.loads(pp.read_text())
        rows[p.stem] = {"status": "ok", **analyze(rec, ratio, probe),
                        "mesh": rec["mesh"], "kind": rec["kind"],
                        "probed": bool(probe and probe.get("status") == "ok")}
        emit(f"roofline/{p.stem}", rows[p.stem].get("roofline_step_s", 0) * 1e6,
             f"{rows[p.stem].get('dominant','-')},frac={rows[p.stem].get('roofline_fraction',0)}")
    save_result("roofline", {"calibration_ratio": ratio, "rows": rows})
    return rows


if __name__ == "__main__":
    main()
