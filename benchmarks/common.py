"""Shared benchmark plumbing: dataset/partition caching, CSV emission."""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
CACHE = ROOT / "experiments" / "bench_cache"
RESULTS = ROOT / "experiments" / "results"

DATASETS = ("sift-like", "deep-like", "ssnpp-like")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The scaffold's CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def save_result(table: str, payload) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{table}.json"
    p.write_text(json.dumps(payload, indent=1, default=str))
    return p


def cached(key: str, fn):
    """Disk-cache numpy dict results of fn()."""
    CACHE.mkdir(parents=True, exist_ok=True)
    p = CACHE / f"{key}.npz"
    if p.exists():
        with np.load(p, allow_pickle=False) as z:
            return dict(z)
    out = fn()
    np.savez_compressed(p, **out)
    return out


def ivf_partition(preset: str, n: int, k: int, seed: int = 0) -> np.ndarray:
    """Cluster assignment for an IVF-k partition of the synthetic dataset.

    Centroids are trained on a 100k subsample (4 iters) and the full set is
    assigned with the chunked JAX kernel — the size distribution (all that
    id-compression rates depend on) matches a full k-means closely.
    """
    from repro.ann.kmeans import assign, kmeans
    from repro.data.synthetic import make_dataset

    def compute():
        base, _ = make_dataset(preset, n, 10, seed=seed)
        sub = base[np.random.default_rng(0).choice(n, min(n, 100_000), replace=False)]
        cents = kmeans(sub, k, iters=4, seed=seed)
        return {"assign": assign(base, cents).astype(np.int32)}

    return cached(f"part_{preset}_{n}_{k}", compute)["assign"]


def graph_adj(preset: str, n: int, r: int, kind: str, seed: int = 0):
    """Cached NSG/HNSW-like adjacency (returns list of np arrays)."""
    from repro.ann.graph import build_hnsw, build_nsg
    from repro.data.synthetic import make_dataset

    CACHE.mkdir(parents=True, exist_ok=True)
    p = CACHE / f"graph_{kind}_{preset}_{n}_{r}.npz"
    if p.exists():
        with np.load(p) as z:
            flat, offs = z["flat"], z["offs"]
        return [flat[offs[i]:offs[i + 1]] for i in range(len(offs) - 1)]
    base, _ = make_dataset(preset, n, 10, seed=seed)
    adj = build_nsg(base, r) if kind == "nsg" else build_hnsw(base, r)
    flat = np.concatenate([a for a in adj]) if adj else np.zeros(0, np.int64)
    offs = np.concatenate([[0], np.cumsum([len(a) for a in adj])]).astype(np.int64)
    np.savez_compressed(p, flat=flat, offs=offs)
    return adj


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
