"""Paper Table 4: billion-scale projection (QINCo + IVF 2^20 setting).

RAM for 1B vectors is not available here; the paper's own quantities are
computed exactly instead, anchored by a REAL measurement: ROC bits/id at
the same per-cluster occupancy (N_k ~= 954) on a 1e6-id index, whose
deviation from the closed form log2(N) - log2(N_k!)/N_k is < 0.1 bit.
The closed form is then evaluated at N=1e9, K=2^20 and the index-size
table (ids + 8-byte QINCo codes) reproduced.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import get_codec

from .common import emit, save_result


def roc_formula_bpe(n_total: int, n_k: float) -> float:
    return math.log2(n_total) - (math.lgamma(n_k + 1) / math.log(2)) / n_k


def measured_anchor(n: int = 1_000_000, k: int = 1 << 10, seed: int = 0):
    """Measure ROC and EF at N_k ~= n/k on a uniform random partition.

    Goes through the ``repro.core.codecs`` registry — the exact payloads
    a factory-built ``IVF<k>,ids=roc|ef`` index stores per cluster — so
    the anchor measures the served representation, not a bespoke loop
    (pre-batched-API call patterns removed).
    """
    rng = np.random.default_rng(seed)
    a = rng.integers(0, k, size=n)
    order = np.argsort(a, kind="stable")
    sizes = np.bincount(a, minlength=k)
    lists = np.split(order, np.cumsum(sizes)[:-1])
    roc, ef = get_codec("roc"), get_codec("ef")
    roc_bits = sum(roc.size_bits(roc.encode(l, n)) for l in lists)
    ef_bits = sum(ef.size_bits(ef.encode(l, n)) for l in lists)
    return roc_bits / n, ef_bits / n, float(np.mean(sizes))


def main(quick: bool = False):
    N = 10**9
    K = 1 << 20
    n_k = N / K  # ~954
    anchor_n = 200_000 if quick else 1_000_000
    anchor_k = anchor_n // 954
    meas_roc, meas_ef, meas_nk = measured_anchor(anchor_n, anchor_k)
    pred_at_anchor = roc_formula_bpe(anchor_n, meas_nk)
    formula_err = abs(meas_roc - pred_at_anchor)

    proj = {
        "unc_bits": 64.0,
        "compact_bits": float(math.ceil(math.log2(N))),
        "roc_bits": roc_formula_bpe(N, n_k),
        "ef_bits": roc_formula_bpe(N, n_k) + 0.56,  # EF's constant gap (§A.1)
        "anchor": {
            "n": anchor_n, "k": anchor_k, "measured_roc": meas_roc,
            "measured_ef": meas_ef, "formula": pred_at_anchor,
            "abs_err_bits": formula_err,
        },
    }
    code_bytes = 8  # QINCo 8-byte codes, recall@10=0.65 setting
    for name, bits in [("unc", 64), ("compact", 30),
                       ("ef", proj["ef_bits"]), ("roc", proj["roc_bits"])]:
        total_gb = (bits / 8 + code_bytes) * N / 1e9
        proj[f"index_gb_{name}"] = total_gb
        emit(f"table4/{name}", 0.0, f"{bits:.2f}b/id,{total_gb:.1f}GB")
    proj["reduction_vs_compact"] = 1 - proj["index_gb_roc"] / proj["index_gb_compact"]
    save_result("table4_large_scale", proj)
    return proj


if __name__ == "__main__":
    main()
