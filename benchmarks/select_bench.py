"""Host vs device top-k select — what the seg_topk kernel path saves.

With ``select=host`` the scan engine pulls the whole padded ``(qb,
C_pad)`` distance block to the host and cuts top-k in numpy; with
``select=device`` the segmented top-k kernel cuts on device and only
the ``(qb, K)`` short-list crosses — results are bit-identical either
way (tests/test_scan_parity.py), so the interesting numbers are wall
time and transferred bytes (``SearchStats.host_block_bytes``).  Sweeps
nprobe x batch on one IVF index, plus the flat kernel path::

    PYTHONPATH=src python -m benchmarks.run --quick --only select
"""

from __future__ import annotations

import numpy as np

from repro.ann.ivf import IVFIndex
from repro.ann.scan import batched_flat_search, batched_search
from repro.data.synthetic import make_dataset

from .common import Timer, emit, save_result

N = 100_000
NLIST = 256
NQ = 256
TOPK = 10
NPROBES = (16, 64, 256)
BATCHES = (32, 128)


def _time_search(idx, queries, nprobe, batch, select):
    # warm the jit cache for this (nprobe, batch, select) shape off-clock
    batched_search(idx, queries[:batch], nprobe=nprobe, topk=TOPK,
                   engine="xla", query_block=batch, select=select,
                   select_min=1)
    with Timer() as t:
        ids, dists, st = batched_search(
            idx, queries, nprobe=nprobe, topk=TOPK, engine="xla",
            query_block=batch, select=select, select_min=1)
    return ids, dists, st, t.s


def main(quick: bool = False) -> None:
    n = N // (10 if quick else 1)
    nq = NQ // (4 if quick else 1)
    nprobes = NPROBES[:2] if quick else NPROBES
    base, queries = make_dataset("sift-like", n, nq, seed=0)
    idx = IVFIndex(nlist=NLIST, id_codec="roc").build(base, seed=1)

    rows = []
    for nprobe in nprobes:
        for batch in BATCHES:
            ih, dh, sh, th = _time_search(idx, queries, nprobe, batch, "host")
            iv, dv, sv, tv = _time_search(idx, queries, nprobe, batch,
                                          "device")
            assert np.array_equal(ih, iv) and np.array_equal(dh, dv), \
                "select=device diverged from select=host"
            name = f"select/ivf_np{nprobe}_b{batch}"
            emit(f"{name}_host", th / nq * 1e6,
                 f"host_MB={sh.host_block_bytes / 1e6:.1f}")
            emit(f"{name}_device", tv / nq * 1e6,
                 f"host_MB={sv.host_block_bytes / 1e6:.1f}"
                 f";speedup={th / tv:.2f}x")
            rows.append({
                "kind": "ivf", "nprobe": nprobe, "batch": batch, "nq": nq,
                "host_us_per_query": th / nq * 1e6,
                "device_us_per_query": tv / nq * 1e6,
                "speedup": th / tv,
                "host_block_bytes_host": int(sh.host_block_bytes),
                "host_block_bytes_device": int(sv.host_block_bytes),
                "device_selects": int(sv.device_select),
            })

    for batch in BATCHES:
        batched_flat_search(base, queries[:batch], topk=TOPK, engine="xla",
                            query_block=batch)        # warm
        with Timer() as t:
            _, _, st = batched_flat_search(base, queries, topk=TOPK,
                                           engine="xla", query_block=batch)
        emit(f"select/flat_b{batch}", t.s / nq * 1e6,
             f"host_MB={st.host_block_bytes / 1e6:.1f}")
        rows.append({
            "kind": "flat", "batch": batch, "nq": nq,
            "device_us_per_query": t.s / nq * 1e6,
            "host_block_bytes_device": int(st.host_block_bytes),
            "device_selects": int(st.device_select),
        })

    save_result("select", {"n": n, "nlist": NLIST, "topk": TOPK,
                           "rows": rows})


if __name__ == "__main__":
    main(quick=True)
