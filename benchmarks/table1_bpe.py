"""Paper Table 1: bits-per-id for IVF and NSG indices, online setting.

IVF{256,512,1024,2048} x {unc64, compact, ef, wt, wt1, roc, gap_ans} on the
three synthetic datasets (N=1e6 default; rates depend only on N and the
cluster-size distribution, which matches the paper's k-means setting — see
DESIGN.md §9).  NSG{16..256} friend-list coding runs at N=1e5 (graph build
is O(N^2); scale noted in EXPERIMENTS.md).  The `saving` column
(compact - bpe) is the scale-free quantity to compare with the paper.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import EliasFano, BigANS, WaveletTree, roc_push_set, set_information_bits
from repro.core.gap_ans import GapAnsCodec

from .common import DATASETS, Timer, emit, graph_adj, ivf_partition, save_result

IVF_KS = (256, 512, 1024, 2048)
NSG_RS = (16, 32, 64)
N_IVF = 1_000_000      # paper scale (sift-like); secondary presets at 300k
N_IVF_SMALL = 300_000
N_GRAPH = 30_000       # shares the graph cache with table3


def ivf_bpe(preset: str, n: int, k: int) -> dict:
    a = ivf_partition(preset, n, k)
    sizes = np.bincount(a, minlength=k)
    order = np.argsort(a, kind="stable")
    lists = np.split(order, np.cumsum(sizes)[:-1])
    logn = math.ceil(math.log2(n))
    out = {"unc64": 64.0, "compact": float(logn)}

    with Timer() as t:
        bits = sum(EliasFano.encode(l, n).size_bits for l in lists)
    out["ef"] = bits / n
    out["ef_enc_s"] = t.s

    with Timer() as t:
        wt = WaveletTree.build(a, k, compressed=False)
    out["wt"] = wt.size_bits / n
    out["wt_enc_s"] = t.s
    with Timer() as t:
        wt1 = WaveletTree.build(a, k, compressed=True)
    out["wt1"] = wt1.size_bits / n
    out["wt1_enc_s"] = t.s

    with Timer() as t:
        bits = 0
        for l in lists:
            ans = BigANS()
            roc_push_set(ans, l, n)
            bits += ans.bits
    out["roc"] = bits / n
    out["roc_enc_s"] = t.s

    gc = GapAnsCodec()
    with Timer() as t:
        bits = sum(gc.size_bits(gc.encode(l, n)) for l in lists)
    out["gap_ans"] = bits / n
    out["gap_enc_s"] = t.s

    # information-theoretic set bound for reference
    out["bound"] = float(
        sum(set_information_bits(n, int(s)) for s in sizes if s) / n
    )
    return out


def graph_bpe(preset: str, n: int, r: int, kind: str = "nsg") -> dict:
    adj = graph_adj(preset, n, r, kind)
    edges = sum(len(x) for x in adj)
    logn = math.ceil(math.log2(n))
    out = {"unc32": 32.0, "compact": float(logn), "edges": edges,
           "avg_degree": edges / n}
    with Timer() as t:
        bits = sum(
            EliasFano.encode(x, n).size_bits for x in adj if len(x))
    out["ef"] = bits / max(1, edges)
    with Timer() as t:
        bits = 0
        for x in adj:
            if not len(x):
                continue
            ans = BigANS()
            roc_push_set(ans, x, n)
            bits += ans.bits
    out["roc"] = bits / max(1, edges)
    out["roc_enc_s"] = t.s
    gcodec = GapAnsCodec()
    bits = sum(gcodec.size_bits(gcodec.encode(x, n)) for x in adj if len(x))
    out["gap_ans"] = bits / max(1, edges)
    return out


def main(quick: bool = False):
    n_graph = 10_000 if quick else N_GRAPH
    rows = {}
    for preset in DATASETS:
        # paper scale for the primary preset; 300k for the others (CPU budget;
        # the scale-free `saving = compact - bpe` column is the comparable one)
        n_ivf = (200_000 if quick else
                 (N_IVF if preset == "sift-like" else N_IVF_SMALL))
        ks = (256, 1024) if (quick or preset != "sift-like") else IVF_KS
        for k in ks:
            key = f"{preset}/IVF{k}"
            rows[key] = {"n": n_ivf, **ivf_bpe(preset, n_ivf, k)}
            emit(f"table1/{key}/roc_bpe", 0.0, f"{rows[key]['roc']:.2f}")
    rs = (16,) if quick else NSG_RS
    for r in rs:  # graph rows: primary preset, cache shared with table3
        key = f"sift-like/NSG{r}"
        rows[key] = graph_bpe("sift-like", n_graph, r)
        emit(f"table1/{key}/roc_bpe", 0.0, f"{rows[key]['roc']:.2f}")
    save_result("table1_bpe", {"n_graph": n_graph, "rows": rows})
    return rows


if __name__ == "__main__":
    main()
